//! Pluggable peer-to-peer transports for decentralized Plan execution.
//!
//! The paper's setting has **no central processor**: each of the `N`
//! participants executes its slice of the schedule and exchanges packets
//! directly with its peers. [`Transport`] is the substrate contract the
//! [`peer`](crate::net::peer) executor runs on: round-synchronous
//! [`send`](Transport::send)/[`recv`](Transport::recv) per port, peer
//! addressing by [`ProcId`], and a [`barrier`](Transport::barrier) per
//! round (the synchronous-round assumption of the cost model — `C1`
//! counts barriers, `C2` counts the per-round maximum message size).
//!
//! Three implementations ship:
//!
//! * [`channel::ChannelTransport`] — in-process `std::sync::mpsc`
//!   channels between threads; the reference substrate tests run on.
//! * [`shmem::ShmemTransport`] — single-producer/single-consumer
//!   shared-memory byte rings per directed pair, carrying the same wire
//!   frames as TCP (lock-free: atomic head/tail cursors over one shared
//!   buffer).
//! * [`tcp::TcpTransport`] — framed TCP sockets over a full mesh,
//!   reusing the `server.rs` wire discipline: the 40-byte
//!   [`FrameHeader`](crate::net::payload::FrameHeader) with its hostile
//!   caps, read timeouts instead of unbounded blocking, and per-stream
//!   FIFO delivery.
//!
//! Every failure is a typed [`TransportError`] — a dropped peer surfaces
//! as [`TransportError::PeerClosed`] or a bounded
//! [`TransportError::Timeout`], never a hang; a frame for the wrong
//! round is [`TransportError::OutOfOrder`] (the schedule is known a
//! priori — Remark 1 — so mis-sequenced traffic is a protocol violation,
//! not something to buffer).

pub mod channel;
pub mod chaos;
pub mod shmem;
pub mod tcp;

pub use channel::ChannelTransport;
pub use chaos::{ChaosSpec, ChaosTransport};
pub use shmem::ShmemTransport;
pub use tcp::{TcpTransport, BARRIER_PORT};

use crate::net::payload::Packet;
use crate::net::sim::ProcId;
use std::collections::BTreeSet;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Which transport substrate peer execution runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TransportKind {
    /// In-process `mpsc` channels (threads).
    Channel,
    /// Shared-memory SPSC ring buffers (threads).
    SharedMem,
    /// Framed TCP sockets (threads or real processes).
    Tcp,
}

impl TransportKind {
    /// All substrates, for conformance sweeps.
    pub const ALL: [TransportKind; 3] =
        [TransportKind::Channel, TransportKind::SharedMem, TransportKind::Tcp];

    /// The substrate requested through the `DCE_TRANSPORT` environment
    /// variable (`channel` | `shmem` | `tcp`), if set and valid. An
    /// unknown value degrades to `None` (the caller's default) with a
    /// stderr note — same discipline as `DCE_FORCE_ISA`, so a typo'd
    /// deployment is visible instead of silently running on channels.
    pub fn from_env() -> Option<TransportKind> {
        let raw = std::env::var("DCE_TRANSPORT").ok()?;
        match raw.parse() {
            Ok(kind) => Some(kind),
            Err(e) => {
                eprintln!(
                    "dce: ignoring DCE_TRANSPORT={raw:?}: {e}; using the default transport"
                );
                None
            }
        }
    }
}

impl std::str::FromStr for TransportKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "channel" | "mpsc" => TransportKind::Channel,
            "shmem" | "shm" | "shared-mem" => TransportKind::SharedMem,
            "tcp" => TransportKind::Tcp,
            other => anyhow::bail!("unknown transport {other:?} (channel|shmem|tcp)"),
        })
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TransportKind::Channel => "channel",
            TransportKind::SharedMem => "shmem",
            TransportKind::Tcp => "tcp",
        })
    }
}

/// Everything a transport can fail with — typed, so the coordinator's
/// unified error surface ([`Error::Transport`](crate::Error)) can route
/// it, and bounded, so a lost peer never hangs the executor.
#[derive(Debug)]
pub enum TransportError {
    /// No traffic from `peer` within the recv/barrier timeout.
    Timeout {
        round: u32,
        peer: ProcId,
        waited: Duration,
    },
    /// `peer` closed its side (crashed, exited, or dropped early).
    PeerClosed { round: u32, peer: ProcId },
    /// A frame tagged for a different round than the one the schedule
    /// expects — mis-sequenced delivery is rejected, never buffered.
    OutOfOrder {
        peer: ProcId,
        expected_round: u32,
        got_round: u32,
    },
    /// A frame on an unexpected port within the right round.
    PortMismatch {
        peer: ProcId,
        round: u32,
        expected_port: u32,
        got_port: u32,
    },
    /// A malformed or hostile frame (bad magic, oversized dimensions —
    /// the `FrameHeader` caps — or a payload that fails to decode).
    Frame { peer: ProcId, detail: String },
    /// A message larger than the shared-memory ring can ever hold.
    RingOverflow { need: usize, capacity: usize },
    /// Socket-level failure underneath the framing.
    Io(std::io::Error),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Timeout {
                round,
                peer,
                waited,
            } => write!(
                f,
                "transport timeout: no traffic from peer {peer} for round {round} within {waited:?}"
            ),
            TransportError::PeerClosed { round, peer } => {
                write!(f, "peer {peer} closed the connection during round {round}")
            }
            TransportError::OutOfOrder {
                peer,
                expected_round,
                got_round,
            } => write!(
                f,
                "out-of-order delivery from peer {peer}: expected round {expected_round}, got round {got_round}"
            ),
            TransportError::PortMismatch {
                peer,
                round,
                expected_port,
                got_port,
            } => write!(
                f,
                "port mismatch from peer {peer} in round {round}: expected port {expected_port}, got {got_port}"
            ),
            TransportError::Frame { peer, detail } => {
                write!(f, "bad frame from peer {peer}: {detail}")
            }
            TransportError::RingOverflow { need, capacity } => write!(
                f,
                "message of {need} bytes exceeds the {capacity}-byte ring capacity"
            ),
            TransportError::Io(e) => write!(f, "transport i/o error: {e}"),
        }
    }
}

impl std::error::Error for TransportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TransportError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> Self {
        TransportError::Io(e)
    }
}

/// One rank's endpoint of a round-synchronous peer mesh.
///
/// The contract mirrors the paper's network model: per round, a
/// processor issues at most `p` sends and `p` receives, each addressed
/// by peer [`ProcId`] and a per-source port number, then crosses the
/// round [`barrier`](Transport::barrier). Delivery between one ordered
/// peer pair is FIFO; rounds never interleave (a frame for round `t+1`
/// arriving while `t` is expected is a typed
/// [`OutOfOrder`](TransportError::OutOfOrder) rejection). All blocking
/// calls are bounded by the transport's recv timeout.
pub trait Transport: Send {
    /// This endpoint's processor id.
    fn rank(&self) -> ProcId;

    /// Every participant in the mesh (including this rank), ascending.
    fn peers(&self) -> &[ProcId];

    /// Ship `rows` to `dst` through send-port `port` for round `round`.
    fn send(
        &mut self,
        round: u32,
        port: u32,
        dst: ProcId,
        rows: &[Packet],
    ) -> Result<(), TransportError>;

    /// Receive the message the schedule expects from `src` on `port` in
    /// `round`. Blocks at most the transport's timeout.
    fn recv(&mut self, round: u32, port: u32, src: ProcId) -> Result<Vec<Packet>, TransportError>;

    /// Round barrier: returns once every rank has entered the barrier
    /// for `round` (bounded by the timeout).
    fn barrier(&mut self, round: u32) -> Result<(), TransportError>;
}

/// Build a full in-process mesh of `procs.len()` endpoints of the given
/// kind — one boxed [`Transport`] per rank, in `procs` order. The TCP
/// flavor binds ephemeral loopback listeners and connects them; see
/// [`tcp::TcpTransport::process_mesh`] for real multi-process use.
///
/// `max_frame_bytes` sizes the shared-memory rings (largest serialized
/// message; ignored by the other kinds); `timeout` bounds every recv
/// and barrier.
pub fn mesh(
    kind: TransportKind,
    procs: &[ProcId],
    ports: usize,
    max_frame_bytes: usize,
    timeout: Duration,
) -> anyhow::Result<Vec<Box<dyn Transport>>> {
    Ok(match kind {
        TransportKind::Channel => channel::ChannelTransport::mesh(procs, timeout)
            .into_iter()
            .map(|t| Box::new(t) as Box<dyn Transport>)
            .collect(),
        TransportKind::SharedMem => {
            shmem::ShmemTransport::mesh(procs, ports, max_frame_bytes, timeout)
                .into_iter()
                .map(|t| Box::new(t) as Box<dyn Transport>)
                .collect()
        }
        TransportKind::Tcp => tcp::TcpTransport::loopback_mesh(procs, timeout)?
            .into_iter()
            .map(|t| Box::new(t) as Box<dyn Transport>)
            .collect(),
    })
}

/// Why a [`LocalBarrier::wait`] gave up: how long it actually waited
/// and which ranks had not arrived at that moment — so the transports
/// can blame a *specific* absent peer instead of guessing.
pub(crate) struct BarrierMiss {
    pub(crate) waited: Duration,
    pub(crate) missing: Vec<ProcId>,
}

/// A reusable generation-counting barrier with a bounded wait — the
/// in-process round barrier shared by the channel and shared-memory
/// transports (`std::sync::Barrier` blocks forever when a peer dies;
/// this one surfaces a typed timeout instead).
///
/// Arrivals are **identified by rank**, not anonymously counted. The
/// old counter design withdrew a timed-out arrival with a decrement;
/// under timeout-then-retry in the same generation, any interleaving
/// that pairs one withdrawal with two arrivals from the same rank
/// releases the barrier with a rank still missing. A set is immune by
/// construction: re-arrival is idempotent, withdrawal removes exactly
/// this rank's entry, and the barrier opens only when every distinct
/// participant is present (pinned by
/// `local_barrier_retry_cannot_double_count`).
pub(crate) struct LocalBarrier {
    procs: Vec<ProcId>,
    state: Mutex<(u64, BTreeSet<ProcId>)>, // (generation, arrived ranks)
    cv: Condvar,
}

impl LocalBarrier {
    pub(crate) fn new(procs: &[ProcId]) -> Self {
        LocalBarrier {
            procs: procs.to_vec(),
            state: Mutex::new((0, BTreeSet::new())),
            cv: Condvar::new(),
        }
    }

    /// Wait as `who` until every participant arrives, or `timeout`
    /// elapses. A rank that timed out may retry in the same
    /// generation: its earlier withdrawn arrival cannot double-count.
    pub(crate) fn wait(&self, who: ProcId, timeout: Duration) -> Result<(), BarrierMiss> {
        let start = Instant::now();
        let deadline = start + timeout;
        let mut st = self.state.lock().expect("barrier lock poisoned");
        let gen = st.0;
        st.1.insert(who);
        if st.1.len() == self.procs.len() {
            st.0 += 1;
            st.1.clear();
            self.cv.notify_all();
            return Ok(());
        }
        while st.0 == gen {
            let now = Instant::now();
            if now >= deadline {
                // Withdraw *our own* arrival so a later retry (or a
                // slow peer arriving after we error out) doesn't see a
                // phantom — removing by rank cannot touch anyone else.
                let missing: Vec<ProcId> = self
                    .procs
                    .iter()
                    .copied()
                    .filter(|p| !st.1.contains(p))
                    .collect();
                st.1.remove(&who);
                return Err(BarrierMiss {
                    waited: start.elapsed(),
                    missing,
                });
            }
            let (guard, _res) = self
                .cv
                .wait_timeout(st, deadline - now)
                .expect("barrier lock poisoned");
            st = guard;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parses_and_displays() {
        for (s, k) in [
            ("channel", TransportKind::Channel),
            ("mpsc", TransportKind::Channel),
            ("shmem", TransportKind::SharedMem),
            ("shm", TransportKind::SharedMem),
            ("tcp", TransportKind::Tcp),
        ] {
            assert_eq!(s.parse::<TransportKind>().unwrap(), k);
        }
        assert!("carrier-pigeon".parse::<TransportKind>().is_err());
        assert_eq!(TransportKind::SharedMem.to_string(), "shmem");
        assert_eq!(
            TransportKind::Tcp.to_string().parse::<TransportKind>().unwrap(),
            TransportKind::Tcp
        );
    }

    #[test]
    fn local_barrier_times_out_instead_of_hanging() {
        let b = LocalBarrier::new(&[0, 1]);
        let t0 = Instant::now();
        let miss = b.wait(0, Duration::from_millis(50)).unwrap_err();
        assert!(miss.waited >= Duration::from_millis(50));
        assert_eq!(miss.missing, vec![1], "the absent rank is named");
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn local_barrier_releases_all_ranks() {
        let b = std::sync::Arc::new(LocalBarrier::new(&[0, 1, 2]));
        std::thread::scope(|s| {
            for rank in 0..3 {
                let b = b.clone();
                s.spawn(move || {
                    for _round in 0..10 {
                        b.wait(rank, Duration::from_secs(5)).unwrap();
                    }
                });
            }
        });
    }

    #[test]
    fn transport_kind_from_env_degrades_with_a_note() {
        // Sequential on purpose: process env is shared state. Restore
        // whatever the harness had (CI pins DCE_TRANSPORT=tcp in one
        // matrix entry).
        let saved = std::env::var("DCE_TRANSPORT").ok();
        std::env::remove_var("DCE_TRANSPORT");
        assert_eq!(TransportKind::from_env(), None);
        std::env::set_var("DCE_TRANSPORT", "shmem");
        assert_eq!(TransportKind::from_env(), Some(TransportKind::SharedMem));
        std::env::set_var("DCE_TRANSPORT", "carrier-pigeon");
        assert_eq!(
            TransportKind::from_env(),
            None,
            "junk degrades to the default, with a stderr note"
        );
        match saved {
            Some(v) => std::env::set_var("DCE_TRANSPORT", v),
            None => std::env::remove_var("DCE_TRANSPORT"),
        }
    }

    /// The satellite regression: with the old anonymous counter, a
    /// timed-out rank that retried in the same generation could pair
    /// one withdrawal with two arrivals and release the barrier while
    /// a rank was still missing. Identified arrivals make re-arrival
    /// idempotent: however many times the lone rank times out and
    /// retries, a 2-party barrier never opens for it alone.
    #[test]
    fn local_barrier_retry_cannot_double_count() {
        let b = LocalBarrier::new(&[0, 1]);
        for attempt in 0..3 {
            let miss = b.wait(0, Duration::from_millis(20)).unwrap_err();
            assert_eq!(
                miss.missing,
                vec![1],
                "attempt {attempt}: rank 0 alone must keep timing out"
            );
        }
        // Generation must be untouched by the failed attempts.
        let st = b.state.lock().unwrap();
        assert_eq!(st.0, 0, "no phantom release happened");
        assert!(st.1.is_empty(), "every withdrawn arrival was cleaned up");
    }

    /// Timeout-then-retry convergence: rank 0 gives up once while rank
    /// 1 is slow, retries the same generation, and both sides converge
    /// — and the *next* generation still works (no leaked state).
    #[test]
    fn local_barrier_timeout_then_retry_converges() {
        let b = std::sync::Arc::new(LocalBarrier::new(&[0, 1]));
        std::thread::scope(|s| {
            let slow = {
                let b = b.clone();
                s.spawn(move || {
                    std::thread::sleep(Duration::from_millis(60));
                    b.wait(1, Duration::from_secs(5)).unwrap();
                    b.wait(1, Duration::from_secs(5)).unwrap();
                })
            };
            assert!(b.wait(0, Duration::from_millis(10)).is_err(), "first try times out");
            b.wait(0, Duration::from_secs(5)).unwrap();
            b.wait(0, Duration::from_secs(5)).unwrap();
            slow.join().unwrap();
        });
    }
}

//! Pluggable peer-to-peer transports for decentralized Plan execution.
//!
//! The paper's setting has **no central processor**: each of the `N`
//! participants executes its slice of the schedule and exchanges packets
//! directly with its peers. [`Transport`] is the substrate contract the
//! [`peer`](crate::net::peer) executor runs on: round-synchronous
//! [`send`](Transport::send)/[`recv`](Transport::recv) per port, peer
//! addressing by [`ProcId`], and a [`barrier`](Transport::barrier) per
//! round (the synchronous-round assumption of the cost model — `C1`
//! counts barriers, `C2` counts the per-round maximum message size).
//!
//! Three implementations ship:
//!
//! * [`channel::ChannelTransport`] — in-process `std::sync::mpsc`
//!   channels between threads; the reference substrate tests run on.
//! * [`shmem::ShmemTransport`] — single-producer/single-consumer
//!   shared-memory byte rings per directed pair, carrying the same wire
//!   frames as TCP (lock-free: atomic head/tail cursors over one shared
//!   buffer).
//! * [`tcp::TcpTransport`] — framed TCP sockets over a full mesh,
//!   reusing the `server.rs` wire discipline: the 40-byte
//!   [`FrameHeader`](crate::net::payload::FrameHeader) with its hostile
//!   caps, read timeouts instead of unbounded blocking, and per-stream
//!   FIFO delivery.
//!
//! Every failure is a typed [`TransportError`] — a dropped peer surfaces
//! as [`TransportError::PeerClosed`] or a bounded
//! [`TransportError::Timeout`], never a hang; a frame for the wrong
//! round is [`TransportError::OutOfOrder`] (the schedule is known a
//! priori — Remark 1 — so mis-sequenced traffic is a protocol violation,
//! not something to buffer).

pub mod channel;
pub mod shmem;
pub mod tcp;

pub use channel::ChannelTransport;
pub use shmem::ShmemTransport;
pub use tcp::{TcpTransport, BARRIER_PORT};

use crate::net::payload::Packet;
use crate::net::sim::ProcId;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Which transport substrate peer execution runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TransportKind {
    /// In-process `mpsc` channels (threads).
    Channel,
    /// Shared-memory SPSC ring buffers (threads).
    SharedMem,
    /// Framed TCP sockets (threads or real processes).
    Tcp,
}

impl TransportKind {
    /// All substrates, for conformance sweeps.
    pub const ALL: [TransportKind; 3] =
        [TransportKind::Channel, TransportKind::SharedMem, TransportKind::Tcp];

    /// The substrate requested through the `DCE_TRANSPORT` environment
    /// variable (`channel` | `shmem` | `tcp`), if set and valid.
    pub fn from_env() -> Option<TransportKind> {
        std::env::var("DCE_TRANSPORT").ok()?.parse().ok()
    }
}

impl std::str::FromStr for TransportKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "channel" | "mpsc" => TransportKind::Channel,
            "shmem" | "shm" | "shared-mem" => TransportKind::SharedMem,
            "tcp" => TransportKind::Tcp,
            other => anyhow::bail!("unknown transport {other:?} (channel|shmem|tcp)"),
        })
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TransportKind::Channel => "channel",
            TransportKind::SharedMem => "shmem",
            TransportKind::Tcp => "tcp",
        })
    }
}

/// Everything a transport can fail with — typed, so the coordinator's
/// unified error surface ([`Error::Transport`](crate::Error)) can route
/// it, and bounded, so a lost peer never hangs the executor.
#[derive(Debug)]
pub enum TransportError {
    /// No traffic from `peer` within the recv/barrier timeout.
    Timeout {
        round: u32,
        peer: ProcId,
        waited: Duration,
    },
    /// `peer` closed its side (crashed, exited, or dropped early).
    PeerClosed { round: u32, peer: ProcId },
    /// A frame tagged for a different round than the one the schedule
    /// expects — mis-sequenced delivery is rejected, never buffered.
    OutOfOrder {
        peer: ProcId,
        expected_round: u32,
        got_round: u32,
    },
    /// A frame on an unexpected port within the right round.
    PortMismatch {
        peer: ProcId,
        round: u32,
        expected_port: u32,
        got_port: u32,
    },
    /// A malformed or hostile frame (bad magic, oversized dimensions —
    /// the `FrameHeader` caps — or a payload that fails to decode).
    Frame { peer: ProcId, detail: String },
    /// A message larger than the shared-memory ring can ever hold.
    RingOverflow { need: usize, capacity: usize },
    /// Socket-level failure underneath the framing.
    Io(std::io::Error),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Timeout {
                round,
                peer,
                waited,
            } => write!(
                f,
                "transport timeout: no traffic from peer {peer} for round {round} within {waited:?}"
            ),
            TransportError::PeerClosed { round, peer } => {
                write!(f, "peer {peer} closed the connection during round {round}")
            }
            TransportError::OutOfOrder {
                peer,
                expected_round,
                got_round,
            } => write!(
                f,
                "out-of-order delivery from peer {peer}: expected round {expected_round}, got round {got_round}"
            ),
            TransportError::PortMismatch {
                peer,
                round,
                expected_port,
                got_port,
            } => write!(
                f,
                "port mismatch from peer {peer} in round {round}: expected port {expected_port}, got {got_port}"
            ),
            TransportError::Frame { peer, detail } => {
                write!(f, "bad frame from peer {peer}: {detail}")
            }
            TransportError::RingOverflow { need, capacity } => write!(
                f,
                "message of {need} bytes exceeds the {capacity}-byte ring capacity"
            ),
            TransportError::Io(e) => write!(f, "transport i/o error: {e}"),
        }
    }
}

impl std::error::Error for TransportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TransportError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> Self {
        TransportError::Io(e)
    }
}

/// One rank's endpoint of a round-synchronous peer mesh.
///
/// The contract mirrors the paper's network model: per round, a
/// processor issues at most `p` sends and `p` receives, each addressed
/// by peer [`ProcId`] and a per-source port number, then crosses the
/// round [`barrier`](Transport::barrier). Delivery between one ordered
/// peer pair is FIFO; rounds never interleave (a frame for round `t+1`
/// arriving while `t` is expected is a typed
/// [`OutOfOrder`](TransportError::OutOfOrder) rejection). All blocking
/// calls are bounded by the transport's recv timeout.
pub trait Transport: Send {
    /// This endpoint's processor id.
    fn rank(&self) -> ProcId;

    /// Every participant in the mesh (including this rank), ascending.
    fn peers(&self) -> &[ProcId];

    /// Ship `rows` to `dst` through send-port `port` for round `round`.
    fn send(
        &mut self,
        round: u32,
        port: u32,
        dst: ProcId,
        rows: &[Packet],
    ) -> Result<(), TransportError>;

    /// Receive the message the schedule expects from `src` on `port` in
    /// `round`. Blocks at most the transport's timeout.
    fn recv(&mut self, round: u32, port: u32, src: ProcId) -> Result<Vec<Packet>, TransportError>;

    /// Round barrier: returns once every rank has entered the barrier
    /// for `round` (bounded by the timeout).
    fn barrier(&mut self, round: u32) -> Result<(), TransportError>;
}

/// Build a full in-process mesh of `procs.len()` endpoints of the given
/// kind — one boxed [`Transport`] per rank, in `procs` order. The TCP
/// flavor binds ephemeral loopback listeners and connects them; see
/// [`tcp::TcpTransport::process_mesh`] for real multi-process use.
///
/// `max_frame_bytes` sizes the shared-memory rings (largest serialized
/// message; ignored by the other kinds); `timeout` bounds every recv
/// and barrier.
pub fn mesh(
    kind: TransportKind,
    procs: &[ProcId],
    ports: usize,
    max_frame_bytes: usize,
    timeout: Duration,
) -> anyhow::Result<Vec<Box<dyn Transport>>> {
    Ok(match kind {
        TransportKind::Channel => channel::ChannelTransport::mesh(procs, timeout)
            .into_iter()
            .map(|t| Box::new(t) as Box<dyn Transport>)
            .collect(),
        TransportKind::SharedMem => {
            shmem::ShmemTransport::mesh(procs, ports, max_frame_bytes, timeout)
                .into_iter()
                .map(|t| Box::new(t) as Box<dyn Transport>)
                .collect()
        }
        TransportKind::Tcp => tcp::TcpTransport::loopback_mesh(procs, timeout)?
            .into_iter()
            .map(|t| Box::new(t) as Box<dyn Transport>)
            .collect(),
    })
}

/// A reusable generation-counting barrier with a bounded wait — the
/// in-process round barrier shared by the channel and shared-memory
/// transports (`std::sync::Barrier` blocks forever when a peer dies;
/// this one surfaces a typed timeout instead).
pub(crate) struct LocalBarrier {
    n: usize,
    state: Mutex<(u64, usize)>, // (generation, arrived)
    cv: Condvar,
}

impl LocalBarrier {
    pub(crate) fn new(n: usize) -> Self {
        LocalBarrier {
            n,
            state: Mutex::new((0, 0)),
            cv: Condvar::new(),
        }
    }

    /// Wait until all `n` ranks arrive, or `timeout` elapses.
    pub(crate) fn wait(&self, timeout: Duration) -> Result<(), Duration> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().expect("barrier lock poisoned");
        let gen = st.0;
        st.1 += 1;
        if st.1 == self.n {
            st.0 += 1;
            st.1 = 0;
            self.cv.notify_all();
            return Ok(());
        }
        while st.0 == gen {
            let now = Instant::now();
            if now >= deadline {
                // Withdraw our arrival so a later retry (or a slow peer
                // arriving after we error out) doesn't see a phantom.
                st.1 = st.1.saturating_sub(1);
                return Err(timeout);
            }
            let (guard, _res) = self
                .cv
                .wait_timeout(st, deadline - now)
                .expect("barrier lock poisoned");
            st = guard;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parses_and_displays() {
        for (s, k) in [
            ("channel", TransportKind::Channel),
            ("mpsc", TransportKind::Channel),
            ("shmem", TransportKind::SharedMem),
            ("shm", TransportKind::SharedMem),
            ("tcp", TransportKind::Tcp),
        ] {
            assert_eq!(s.parse::<TransportKind>().unwrap(), k);
        }
        assert!("carrier-pigeon".parse::<TransportKind>().is_err());
        assert_eq!(TransportKind::SharedMem.to_string(), "shmem");
        assert_eq!(
            TransportKind::Tcp.to_string().parse::<TransportKind>().unwrap(),
            TransportKind::Tcp
        );
    }

    #[test]
    fn local_barrier_times_out_instead_of_hanging() {
        let b = LocalBarrier::new(2);
        let t0 = Instant::now();
        let err = b.wait(Duration::from_millis(50)).unwrap_err();
        assert_eq!(err, Duration::from_millis(50));
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn local_barrier_releases_all_ranks() {
        let b = std::sync::Arc::new(LocalBarrier::new(3));
        std::thread::scope(|s| {
            for _ in 0..3 {
                let b = b.clone();
                s.spawn(move || {
                    for _round in 0..10 {
                        b.wait(Duration::from_secs(5)).unwrap();
                    }
                });
            }
        });
    }
}

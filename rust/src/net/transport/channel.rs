//! In-process channel transport: one `std::sync::mpsc` channel per
//! directed peer pair, the reference [`Transport`] substrate.
//!
//! Messages travel as owned `Vec<Packet>` — no serialization — so this
//! is the fastest substrate and the one the conformance suite leans on
//! as the cross-check for the byte-level ones (shmem, TCP). Round
//! discipline is still enforced: a message tagged with the wrong round
//! or port is a typed rejection, exactly like the framed transports.

use super::{LocalBarrier, Transport, TransportError};
use crate::net::payload::Packet;
use crate::net::sim::ProcId;
use std::collections::HashMap;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

struct WireMsg {
    round: u32,
    port: u32,
    rows: Vec<Packet>,
}

/// One rank's endpoint of an mpsc mesh built by
/// [`ChannelTransport::mesh`].
pub struct ChannelTransport {
    rank: ProcId,
    procs: Vec<ProcId>,
    txs: HashMap<ProcId, Sender<WireMsg>>,
    rxs: HashMap<ProcId, Receiver<WireMsg>>,
    barrier: Arc<LocalBarrier>,
    timeout: Duration,
}

impl ChannelTransport {
    /// Build a full mesh over `procs`: one endpoint per rank, connected
    /// by a dedicated channel per directed pair, sharing one round
    /// barrier. Every recv and barrier is bounded by `timeout`.
    pub fn mesh(procs: &[ProcId], timeout: Duration) -> Vec<ChannelTransport> {
        let barrier = Arc::new(LocalBarrier::new(procs));
        // senders[dst][src] / receivers[dst][src]
        let mut rx_for: HashMap<ProcId, HashMap<ProcId, Receiver<WireMsg>>> =
            procs.iter().map(|&p| (p, HashMap::new())).collect();
        let mut tx_for: HashMap<ProcId, HashMap<ProcId, Sender<WireMsg>>> =
            procs.iter().map(|&p| (p, HashMap::new())).collect();
        for &src in procs {
            for &dst in procs {
                if src == dst {
                    continue;
                }
                let (tx, rx) = mpsc::channel();
                tx_for.get_mut(&src).unwrap().insert(dst, tx);
                rx_for.get_mut(&dst).unwrap().insert(src, rx);
            }
        }
        procs
            .iter()
            .map(|&rank| ChannelTransport {
                rank,
                procs: procs.to_vec(),
                txs: tx_for.remove(&rank).unwrap(),
                rxs: rx_for.remove(&rank).unwrap(),
                barrier: barrier.clone(),
                timeout,
            })
            .collect()
    }
}

impl Transport for ChannelTransport {
    fn rank(&self) -> ProcId {
        self.rank
    }

    fn peers(&self) -> &[ProcId] {
        &self.procs
    }

    fn send(
        &mut self,
        round: u32,
        port: u32,
        dst: ProcId,
        rows: &[Packet],
    ) -> Result<(), TransportError> {
        let tx = self
            .txs
            .get(&dst)
            .ok_or(TransportError::PeerClosed { round, peer: dst })?;
        tx.send(WireMsg {
            round,
            port,
            rows: rows.to_vec(),
        })
        .map_err(|_| TransportError::PeerClosed { round, peer: dst })
    }

    fn recv(&mut self, round: u32, port: u32, src: ProcId) -> Result<Vec<Packet>, TransportError> {
        let rx = self
            .rxs
            .get(&src)
            .ok_or(TransportError::PeerClosed { round, peer: src })?;
        let msg = rx.recv_timeout(self.timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => TransportError::Timeout {
                round,
                peer: src,
                waited: self.timeout,
            },
            RecvTimeoutError::Disconnected => TransportError::PeerClosed { round, peer: src },
        })?;
        if msg.round != round {
            return Err(TransportError::OutOfOrder {
                peer: src,
                expected_round: round,
                got_round: msg.round,
            });
        }
        if msg.port != port {
            return Err(TransportError::PortMismatch {
                peer: src,
                round,
                expected_port: port,
                got_port: msg.port,
            });
        }
        Ok(msg.rows)
    }

    fn barrier(&mut self, round: u32) -> Result<(), TransportError> {
        self.barrier.wait(self.rank, self.timeout).map_err(|miss| {
            // Blame the first rank that had not arrived when we gave up.
            let peer = miss.missing.first().copied().unwrap_or(self.rank);
            TransportError::Timeout {
                round,
                peer,
                waited: miss.waited,
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_two_ranks() {
        let mut mesh = ChannelTransport::mesh(&[0, 1], Duration::from_secs(2));
        let mut t1 = mesh.pop().unwrap();
        let mut t0 = mesh.pop().unwrap();
        std::thread::scope(|s| {
            s.spawn(move || {
                t0.send(0, 0, 1, &[vec![1, 2], vec![3, 4]]).unwrap();
                t0.barrier(0).unwrap();
            });
            s.spawn(move || {
                let rows = t1.recv(0, 0, 0).unwrap();
                assert_eq!(rows, vec![vec![1, 2], vec![3, 4]]);
                t1.barrier(0).unwrap();
            });
        });
    }

    #[test]
    fn wrong_round_is_out_of_order() {
        let mut mesh = ChannelTransport::mesh(&[0, 1], Duration::from_secs(2));
        let mut t1 = mesh.pop().unwrap();
        let mut t0 = mesh.pop().unwrap();
        t0.send(7, 0, 1, &[vec![9]]).unwrap();
        match t1.recv(0, 0, 0) {
            Err(TransportError::OutOfOrder {
                expected_round: 0,
                got_round: 7,
                ..
            }) => {}
            other => panic!("expected OutOfOrder, got {other:?}"),
        }
    }

    #[test]
    fn dropped_peer_is_typed_not_a_hang() {
        let mut mesh = ChannelTransport::mesh(&[0, 1], Duration::from_millis(100));
        let t1 = mesh.pop().unwrap();
        let mut t0 = mesh.pop().unwrap();
        drop(t1);
        match t0.recv(0, 0, 1) {
            Err(TransportError::PeerClosed { peer: 1, .. }) => {}
            other => panic!("expected PeerClosed, got {other:?}"),
        }
    }
}

//! Seeded, deterministic fault injection at the frame layer.
//!
//! [`ChaosTransport`] decorates any [`Transport`] — channel, shmem, or
//! TCP — and perturbs its traffic according to a [`ChaosSpec`]:
//!
//! * **Transient** faults (stragglers via per-link delay, duplicated
//!   frames, reorder-within-round) surface as the same typed
//!   [`TransportError`]s a hostile network would produce. A hardened
//!   executor must absorb them completely: outputs stay bit-identical
//!   to a healthy run.
//! * **Permanent** faults (crash-at-round, partitioned links,
//!   single-round erasures) reuse the [`FaultSpec`] vocabulary of the
//!   round simulator, so one scenario drives both the simulator
//!   ([`fault::analyze_plan`](crate::net::fault::analyze_plan)) and the
//!   real mesh — that equivalence is what `tests/chaos.rs` asserts.
//!
//! Every decision is a pure function of `(seed, fault kind, round,
//! port, src, dst)` — no RNG state, no wall clock — so a scenario
//! replays identically across transports, thread schedules, and
//! processes. Crucially, injected failures are *synthesized before
//! touching the inner transport*: the real frame stays queued in
//! order, so a retry after an injected timeout finds the genuine
//! payload and the substrate's strict round/port FIFO is never
//! poisoned.

use crate::net::fault::FaultSpec;
use crate::net::payload::Packet;
use crate::net::sim::ProcId;
use crate::net::transport::{Transport, TransportError};
use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::time::Duration;

/// Salt constants keep the per-fault-kind hash streams independent:
/// whether a link is delayed says nothing about whether it duplicates.
const SALT_DELAY: u64 = 0x9E37_79B9_7F4A_7C15;
const SALT_DUP: u64 = 0xC2B2_AE3D_27D4_EB4F;
const SALT_REORDER: u64 = 0x1656_67B1_9E37_79F9;

/// A deterministic chaos scenario: transient knobs (per-mille rates
/// under a seed) plus permanent directives borrowed verbatim from the
/// [`FaultSpec`] vocabulary (1-based rounds, [`POST_RUN`] sentinel).
///
/// [`POST_RUN`]: crate::net::fault::POST_RUN
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ChaosSpec {
    /// Seed for every injection decision.
    pub seed: u64,
    /// Per-mille (0..=1000) chance a scheduled receive is a straggler.
    pub delay_per_mille: u16,
    /// How many consecutive timeouts a straggler costs (normalised to
    /// at least 1; keep below the executor's retry budget).
    pub delay_attempts: u32,
    /// Per-mille chance a delivered frame is followed by a stale
    /// duplicate on the same link.
    pub dup_per_mille: u16,
    /// Per-mille chance the frames of one round arrive port-swapped.
    pub reorder_per_mille: u16,
    /// `pid -> first dead round` (1-based), exactly like `FaultSpec`.
    crashes: BTreeMap<ProcId, u64>,
    /// Directed links that never deliver (partition edges).
    partitions: BTreeSet<(ProcId, ProcId)>,
    /// Single-round erasures `(round, src, dst)`, 1-based.
    erasures: BTreeSet<(u64, ProcId, ProcId)>,
}

impl ChaosSpec {
    pub fn new() -> Self {
        ChaosSpec::default()
    }

    /// No faults at all — the decorator becomes a pass-through.
    pub fn is_empty(&self) -> bool {
        *self == ChaosSpec::default() || {
            self.delay_per_mille == 0
                && self.dup_per_mille == 0
                && self.reorder_per_mille == 0
                && self.crashes.is_empty()
                && self.partitions.is_empty()
                && self.erasures.is_empty()
        }
    }

    /// Only transient faults (delay/dup/reorder) — the hardened
    /// executor must absorb these bit-identically, with no degraded
    /// report.
    pub fn is_transient_only(&self) -> bool {
        self.crashes.is_empty() && self.partitions.is_empty() && self.erasures.is_empty()
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Straggle `per_mille`‰ of receives for `attempts` timeouts each.
    pub fn delay(mut self, per_mille: u16, attempts: u32) -> Self {
        self.delay_per_mille = per_mille.min(1000);
        self.delay_attempts = attempts;
        self
    }

    /// Duplicate `per_mille`‰ of delivered frames.
    pub fn dup(mut self, per_mille: u16) -> Self {
        self.dup_per_mille = per_mille.min(1000);
        self
    }

    /// Port-swap `per_mille`‰ of within-round deliveries.
    pub fn reorder(mut self, per_mille: u16) -> Self {
        self.reorder_per_mille = per_mille.min(1000);
        self
    }

    /// `pid` is dead for the entire run (from round 1 on).
    pub fn crash(self, pid: ProcId) -> Self {
        self.crash_from(pid, 1)
    }

    /// `pid` is dead from 1-based `round` on.
    pub fn crash_from(mut self, pid: ProcId, round: u64) -> Self {
        let r = self.crashes.entry(pid).or_insert(round);
        *r = (*r).min(round);
        self
    }

    /// `pid` executes every round healthily, then its output is lost —
    /// [`POST_RUN`](crate::net::fault::POST_RUN) storage loss.
    pub fn crash_after(self, pid: ProcId) -> Self {
        self.crash_from(pid, crate::net::fault::POST_RUN)
    }

    /// The directed link `src -> dst` never delivers.
    pub fn partition(mut self, src: ProcId, dst: ProcId) -> Self {
        self.partitions.insert((src, dst));
        self
    }

    /// Cut every directed link between the two groups (a network
    /// partition: `a` and `b` can no longer talk in either direction).
    pub fn split(mut self, a: &[ProcId], b: &[ProcId]) -> Self {
        for &x in a {
            for &y in b {
                self.partitions.insert((x, y));
                self.partitions.insert((y, x));
            }
        }
        self
    }

    /// Drop exactly the message `src -> dst` of 1-based `round`.
    pub fn erase(mut self, round: u64, src: ProcId, dst: ProcId) -> Self {
        self.erasures.insert((round, src, dst));
        self
    }

    /// The permanent directives as a [`FaultSpec`], so the simulator's
    /// [`analyze_plan`](crate::net::fault::analyze_plan) predicts what
    /// the chaos-wrapped mesh will produce.
    pub fn to_fault_spec(&self) -> FaultSpec {
        let mut spec = FaultSpec::new();
        for (&pid, &round) in &self.crashes {
            spec = spec.crash_from(pid, round);
        }
        for &(src, dst) in &self.partitions {
            spec = spec.drop_link(src, dst);
        }
        for &(round, src, dst) in &self.erasures {
            spec = spec.erase(round, src, dst);
        }
        spec
    }

    /// Mirror a simulator [`FaultSpec`] onto the wire (the inverse of
    /// [`to_fault_spec`](ChaosSpec::to_fault_spec); transient knobs
    /// stay zero — the simulator has no notion of them).
    pub fn from_fault_spec(spec: &FaultSpec) -> Self {
        let mut chaos = ChaosSpec::new();
        for (pid, round) in spec.crash_entries() {
            chaos = chaos.crash_from(pid, round);
        }
        for (src, dst) in spec.link_entries() {
            chaos = chaos.partition(src, dst);
        }
        for (round, src, dst) in spec.erasure_entries() {
            chaos = chaos.erase(round, src, dst);
        }
        chaos
    }

    /// Is `pid` dead at 1-based round `t1`?
    fn crashed_at(&self, pid: ProcId, t1: u64) -> bool {
        self.crashes.get(&pid).is_some_and(|&r| t1 >= r)
    }

    /// Is the directed message `src -> dst` of round `t1` cut?
    fn cut(&self, t1: u64, src: ProcId, dst: ProcId) -> bool {
        self.partitions.contains(&(src, dst)) || self.erasures.contains(&(t1, src, dst))
    }

    /// Crash directives `(pid, first dead round)` for the harness.
    pub(crate) fn crash_entries(&self) -> impl Iterator<Item = (ProcId, u64)> + '_ {
        self.crashes.iter().map(|(&p, &r)| (p, r))
    }

    /// The scenario requested through `DCE_CHAOS`, if set and valid.
    /// Unknown or malformed values degrade to no chaos with a stderr
    /// note — same discipline as `DCE_FORCE_ISA`.
    pub fn from_env() -> Option<ChaosSpec> {
        let raw = std::env::var("DCE_CHAOS").ok()?;
        match raw.parse::<ChaosSpec>() {
            Ok(spec) if spec.is_empty() => None,
            Ok(spec) => Some(spec),
            Err(e) => {
                eprintln!("dce: ignoring DCE_CHAOS={raw:?}: {e}; running without chaos");
                None
            }
        }
    }
}

/// `DCE_CHAOS` grammar: comma-separated `key=value` pairs, all
/// transient (permanent faults need a schedule-aware harness, not an
/// env knob). Keys: `delay`/`dup`/`reorder` (per-mille, 0..=1000),
/// `attempts` (1..=3 timeouts per straggler), `seed` (u64). `off`,
/// `none`, and the empty string mean no chaos.
impl std::str::FromStr for ChaosSpec {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        let s = s.trim();
        if s.is_empty() || s == "off" || s == "none" {
            return Ok(ChaosSpec::default());
        }
        let mut spec = ChaosSpec::default();
        for pair in s.split(',') {
            let (key, value) = pair
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("expected key=value, got {pair:?}"))?;
            let key = key.trim();
            let value = value.trim();
            let per_mille = || -> anyhow::Result<u16> {
                let v: u16 = value.parse()?;
                anyhow::ensure!(v <= 1000, "{key} is per-mille (0..=1000), got {v}");
                Ok(v)
            };
            match key {
                "delay" => spec.delay_per_mille = per_mille()?,
                "dup" => spec.dup_per_mille = per_mille()?,
                "reorder" => spec.reorder_per_mille = per_mille()?,
                "attempts" => {
                    let v: u32 = value.parse()?;
                    anyhow::ensure!(
                        (1..=3).contains(&v),
                        "attempts must be 1..=3 (stay under the retry budget), got {v}"
                    );
                    spec.delay_attempts = v;
                }
                "seed" => spec.seed = value.parse()?,
                other => anyhow::bail!(
                    "unknown chaos key {other:?} (delay|dup|reorder|attempts|seed)"
                ),
            }
        }
        Ok(spec)
    }
}

impl std::fmt::Display for ChaosSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_empty() {
            return f.write_str("off");
        }
        let mut sep = "";
        let mut put = |f: &mut std::fmt::Formatter<'_>, part: String| {
            let r = write!(f, "{sep}{part}");
            sep = ",";
            r
        };
        if self.delay_per_mille > 0 {
            put(f, format!("delay={}", self.delay_per_mille))?;
            put(f, format!("attempts={}", self.delay_attempts.max(1)))?;
        }
        if self.dup_per_mille > 0 {
            put(f, format!("dup={}", self.dup_per_mille))?;
        }
        if self.reorder_per_mille > 0 {
            put(f, format!("reorder={}", self.reorder_per_mille))?;
        }
        if self.seed != 0 {
            put(f, format!("seed={}", self.seed))?;
        }
        if !self.is_transient_only() {
            put(
                f,
                format!(
                    "+{} crash/{} link/{} erase",
                    self.crashes.len(),
                    self.partitions.len(),
                    self.erasures.len()
                ),
            )?;
        }
        Ok(())
    }
}

/// splitmix64 — the same tiny deterministic mixer `FaultSpec` uses for
/// `random_crashes`; enough bits to make per-mille draws unbiased.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// One decision hash per `(fault kind, round, port, src, dst)` event.
fn event_hash(seed: u64, salt: u64, round: u32, port: u32, src: ProcId, dst: ProcId) -> u64 {
    let mut h = mix(seed ^ salt);
    h = mix(h ^ (((round as u64) << 32) | port as u64));
    h = mix(h ^ (((src as u64) << 32) | dst as u64));
    h
}

fn fires(h: u64, per_mille: u16) -> bool {
    per_mille > 0 && h % 1000 < per_mille as u64
}

/// The decorator: wraps any substrate and injects the spec's faults.
///
/// Synthesized failures never consume from the inner transport, so the
/// substrate's FIFO discipline survives retries; permanent drops
/// swallow the send side (the frame is simply never shipped), so the
/// receiver observes exactly the silence a real partition produces.
pub struct ChaosTransport {
    inner: Box<dyn Transport>,
    spec: ChaosSpec,
    /// Remaining injected timeouts per in-flight `(round, port, src)`.
    delay_left: HashMap<(u32, u32, ProcId), u32>,
    /// Reorder already injected for `(round, port, src)`.
    reordered: HashSet<(u32, u32, ProcId)>,
    /// Pending stale duplicate per link: the `(round, port)` of the
    /// frame that was delivered twice.
    stale: HashMap<ProcId, (u32, u32)>,
}

impl ChaosTransport {
    pub fn wrap(inner: Box<dyn Transport>, spec: ChaosSpec) -> Self {
        ChaosTransport {
            inner,
            spec,
            delay_left: HashMap::new(),
            reordered: HashSet::new(),
            stale: HashMap::new(),
        }
    }

    /// The scenario this endpoint runs under.
    pub fn spec(&self) -> &ChaosSpec {
        &self.spec
    }
}

impl Transport for ChaosTransport {
    fn rank(&self) -> ProcId {
        self.inner.rank()
    }

    fn peers(&self) -> &[ProcId] {
        self.inner.peers()
    }

    fn send(
        &mut self,
        round: u32,
        port: u32,
        dst: ProcId,
        rows: &[Packet],
    ) -> Result<(), TransportError> {
        let me = self.inner.rank();
        let t1 = round as u64 + 1; // FaultSpec rounds are 1-based
        if self.spec.crashed_at(me, t1) {
            // The sentinel a rank's own crash surfaces as: its first
            // wire operation of the dead round fails self-addressed.
            return Err(TransportError::PeerClosed { round, peer: me });
        }
        if self.spec.crashed_at(dst, t1) || self.spec.cut(t1, me, dst) {
            // A dead or partitioned destination: the frame vanishes.
            // The receiver sees pure silence, exactly like the real
            // failure — no half-delivered state to clean up.
            return Ok(());
        }
        self.inner.send(round, port, dst, rows)
    }

    fn recv(&mut self, round: u32, port: u32, src: ProcId) -> Result<Vec<Packet>, TransportError> {
        let me = self.inner.rank();
        let t1 = round as u64 + 1;
        if self.spec.crashed_at(me, t1) {
            return Err(TransportError::PeerClosed { round, peer: me });
        }
        if self.spec.crashed_at(src, t1) {
            return Err(TransportError::PeerClosed { round, peer: src });
        }
        if self.spec.cut(t1, src, me) {
            // Partition/erasure: silence. The executor's bounded wait
            // expires; report it as already-elapsed so tests stay fast.
            return Err(TransportError::Timeout {
                round,
                peer: src,
                waited: Duration::ZERO,
            });
        }
        // A stale duplicate from an earlier exchange arrives first.
        if let Some(&(sr, sp)) = self.stale.get(&src) {
            self.stale.remove(&src);
            if sr != round {
                return Err(TransportError::OutOfOrder {
                    peer: src,
                    expected_round: round,
                    got_round: sr,
                });
            }
            if sp != port {
                return Err(TransportError::PortMismatch {
                    peer: src,
                    round,
                    expected_port: port,
                    got_port: sp,
                });
            }
            // Duplicate of the very frame we are about to read: the
            // substrate would de-dup it by FIFO position; drop it.
        }
        // Straggler: charge the configured number of timeouts before
        // letting the (already queued) genuine frame through.
        let key = (round, port, src);
        let budget = self.spec.delay_attempts.max(1);
        match self.delay_left.entry(key) {
            std::collections::hash_map::Entry::Vacant(v) => {
                let h = event_hash(self.spec.seed, SALT_DELAY, round, port, src, me);
                if fires(h, self.spec.delay_per_mille) {
                    v.insert(budget - 1);
                    return Err(TransportError::Timeout {
                        round,
                        peer: src,
                        waited: Duration::ZERO,
                    });
                }
            }
            std::collections::hash_map::Entry::Occupied(mut o) => {
                if *o.get() > 0 {
                    *o.get_mut() -= 1;
                    return Err(TransportError::Timeout {
                        round,
                        peer: src,
                        waited: Duration::ZERO,
                    });
                }
            }
        }
        // Reorder-within-round: the link's other-port frame shows up
        // first, exactly once; the retry finds the right one.
        let rh = event_hash(self.spec.seed, SALT_REORDER, round, port, src, me);
        if fires(rh, self.spec.reorder_per_mille) && self.reordered.insert(key) {
            return Err(TransportError::PortMismatch {
                peer: src,
                round,
                expected_port: port,
                got_port: port ^ 1,
            });
        }
        let rows = self.inner.recv(round, port, src)?;
        self.delay_left.remove(&key);
        let dh = event_hash(self.spec.seed, SALT_DUP, round, port, src, me);
        if fires(dh, self.spec.dup_per_mille) {
            self.stale.insert(src, (round, port));
        }
        Ok(rows)
    }

    fn barrier(&mut self, round: u32) -> Result<(), TransportError> {
        // Barriers always pass through: a crashed rank's *executor*
        // decides whether to keep crossing them (the ghost protocol in
        // `net::peer`), and transient faults never touch the barrier —
        // the round structure is the one invariant chaos preserves.
        self.inner.barrier(round)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::channel::ChannelTransport;

    fn chaos_pair(spec: ChaosSpec) -> (ChaosTransport, ChaosTransport) {
        let mut mesh = ChannelTransport::mesh(&[0, 1], Duration::from_millis(200));
        let b = mesh.pop().unwrap();
        let a = mesh.pop().unwrap();
        (
            ChaosTransport::wrap(Box::new(a), spec.clone()),
            ChaosTransport::wrap(Box::new(b), spec),
        )
    }

    #[test]
    fn decisions_are_deterministic_and_rate_bounded() {
        assert_eq!(
            event_hash(7, SALT_DELAY, 3, 1, 0, 2),
            event_hash(7, SALT_DELAY, 3, 1, 0, 2)
        );
        assert_ne!(
            event_hash(7, SALT_DELAY, 3, 1, 0, 2),
            event_hash(7, SALT_DUP, 3, 1, 0, 2),
            "fault kinds draw from independent streams"
        );
        for h in 0..10_000u64 {
            assert!(!fires(mix(h), 0), "rate 0 never fires");
            assert!(fires(mix(h), 1000), "rate 1000 always fires");
        }
    }

    #[test]
    fn delay_charges_timeouts_then_delivers_intact() {
        let spec = ChaosSpec::new().delay(1000, 2).with_seed(5);
        let (mut a, mut b) = chaos_pair(spec);
        a.send(0, 0, 1, &[vec![1, 2, 3]]).unwrap();
        for attempt in 0..2 {
            match b.recv(0, 0, 0) {
                Err(TransportError::Timeout { round: 0, peer: 0, .. }) => {}
                other => panic!("attempt {attempt}: expected injected Timeout, got {other:?}"),
            }
        }
        assert_eq!(b.recv(0, 0, 0).unwrap(), vec![vec![1, 2, 3]]);
    }

    #[test]
    fn dup_surfaces_one_stale_frame_then_heals() {
        let spec = ChaosSpec::new().dup(1000);
        let (mut a, mut b) = chaos_pair(spec);
        a.send(0, 0, 1, &[vec![7]]).unwrap();
        assert_eq!(b.recv(0, 0, 0).unwrap(), vec![vec![7]]);
        b.barrier(0).unwrap_err(); // only one of two ranks arrives
        a.send(1, 0, 1, &[vec![8]]).unwrap();
        match b.recv(1, 0, 0) {
            Err(TransportError::OutOfOrder {
                peer: 0,
                expected_round: 1,
                got_round: 0,
            }) => {}
            other => panic!("expected the stale round-0 duplicate, got {other:?}"),
        }
        assert_eq!(b.recv(1, 0, 0).unwrap(), vec![vec![8]], "retry heals");
    }

    #[test]
    fn reorder_swaps_ports_exactly_once() {
        let spec = ChaosSpec::new().reorder(1000);
        let (mut a, mut b) = chaos_pair(spec);
        a.send(0, 0, 1, &[vec![9]]).unwrap();
        match b.recv(0, 0, 0) {
            Err(TransportError::PortMismatch {
                peer: 0,
                round: 0,
                expected_port: 0,
                got_port: 1,
            }) => {}
            other => panic!("expected injected PortMismatch, got {other:?}"),
        }
        assert_eq!(b.recv(0, 0, 0).unwrap(), vec![vec![9]]);
    }

    #[test]
    fn crash_directives_surface_as_typed_sentinels() {
        let spec = ChaosSpec::new().crash_from(0, 1);
        let (mut a, mut b) = chaos_pair(spec);
        // The dead rank's own sends fail self-addressed...
        match a.send(0, 0, 1, &[vec![1]]) {
            Err(TransportError::PeerClosed { round: 0, peer: 0 }) => {}
            other => panic!("expected self-addressed PeerClosed, got {other:?}"),
        }
        // ...and the survivor sees the crash as PeerClosed{src}.
        match b.recv(0, 0, 0) {
            Err(TransportError::PeerClosed { round: 0, peer: 0 }) => {}
            other => panic!("expected PeerClosed from dead src, got {other:?}"),
        }
        // Sends *to* the dead rank are swallowed, not errors.
        b.send(0, 0, 0, &[vec![2]]).unwrap();
    }

    #[test]
    fn crash_round_gates_by_one_based_round() {
        let spec = ChaosSpec::new().crash_from(0, 2); // healthy in round 0 (t1=1)
        let (mut a, mut b) = chaos_pair(spec);
        a.send(0, 0, 1, &[vec![3]]).unwrap();
        assert_eq!(b.recv(0, 0, 0).unwrap(), vec![vec![3]]);
        match a.send(1, 0, 1, &[vec![4]]) {
            Err(TransportError::PeerClosed { round: 1, peer: 0 }) => {}
            other => panic!("round 1 (t1=2) must be dead, got {other:?}"),
        }
    }

    #[test]
    fn partitions_and_erasures_are_directed_silence() {
        let spec = ChaosSpec::new().partition(0, 1).erase(1, 1, 0);
        let (mut a, mut b) = chaos_pair(spec);
        a.send(0, 0, 1, &[vec![1]]).unwrap(); // swallowed
        match b.recv(0, 0, 0) {
            Err(TransportError::Timeout { round: 0, peer: 0, .. }) => {}
            other => panic!("cut link must be silence, got {other:?}"),
        }
        // Reverse direction of the partition is untouched.
        b.send(0, 0, 0, &[vec![2]]).unwrap();
        assert_eq!(a.recv(0, 0, 1).unwrap(), vec![vec![2]]);
        // The erasure hits exactly round 1 (t1=2) of link 1 -> 0.
        b.send(1, 0, 0, &[vec![3]]).unwrap();
        match a.recv(1, 0, 1) {
            Err(TransportError::Timeout { round: 1, peer: 1, .. }) => {}
            other => panic!("erased message must be silence, got {other:?}"),
        }
        b.send(2, 0, 0, &[vec![4]]).unwrap();
        // Round 1's frame is still queued under the cut — the channel
        // substrate rejects it as OutOfOrder when round 2 reads it, so
        // drain it first the way the hardened executor's known-dead
        // bookkeeping does: skip the recv entirely. Here we just
        // assert the erasure did not leak into a *different* round's
        // verdict by opening a fresh pair.
        let spec2 = ChaosSpec::new().erase(1, 1, 0);
        let (mut a2, mut b2) = chaos_pair(spec2);
        b2.send(0, 0, 0, &[vec![5]]).unwrap();
        assert_eq!(a2.recv(0, 0, 1).unwrap(), vec![vec![5]]);
    }

    #[test]
    fn fault_spec_roundtrip_preserves_permanent_directives() {
        let chaos = ChaosSpec::new()
            .crash_from(2, 3)
            .crash_after(4)
            .partition(0, 1)
            .erase(2, 1, 0);
        let spec = chaos.to_fault_spec();
        assert!(spec.crashed_by(2, 3) && !spec.crashed_by(2, 2));
        assert!(spec.is_crashed(4));
        assert_eq!(ChaosSpec::from_fault_spec(&spec), chaos);
        assert!(!chaos.is_transient_only());
        assert!(ChaosSpec::new().delay(10, 1).is_transient_only());
    }

    #[test]
    fn spec_parses_like_an_env_knob() {
        let spec: ChaosSpec = "delay=200,attempts=2,dup=50,reorder=50,seed=42"
            .parse()
            .unwrap();
        assert_eq!(spec.delay_per_mille, 200);
        assert_eq!(spec.delay_attempts, 2);
        assert_eq!(spec.dup_per_mille, 50);
        assert_eq!(spec.reorder_per_mille, 50);
        assert_eq!(spec.seed, 42);
        assert!(spec.is_transient_only());
        for ok_empty in ["", "off", "none", "  "] {
            assert!(ok_empty.parse::<ChaosSpec>().unwrap().is_empty());
        }
        for junk in [
            "delay",      // no value
            "delay=1001", // over per-mille
            "attempts=0", // under budget floor
            "attempts=9", // over budget cap
            "gremlins=5", // unknown key
            "seed=abc",   // unparseable
        ] {
            assert!(junk.parse::<ChaosSpec>().is_err(), "{junk:?} must be rejected");
        }
        // Display round-trips the transient knobs.
        let shown = spec.to_string();
        assert_eq!(shown.parse::<ChaosSpec>().unwrap(), spec);
        assert_eq!(ChaosSpec::default().to_string(), "off");
    }

    #[test]
    fn from_env_degrades_to_none_with_a_note() {
        // Sequential on purpose: process env is shared state. Restore
        // whatever the harness had (CI pins DCE_CHAOS in its chaos
        // smoke entry).
        let saved = std::env::var("DCE_CHAOS").ok();
        std::env::remove_var("DCE_CHAOS");
        assert_eq!(ChaosSpec::from_env(), None);
        std::env::set_var("DCE_CHAOS", "delay=100,seed=1");
        assert_eq!(
            ChaosSpec::from_env(),
            Some(ChaosSpec::new().delay(100, 0).with_seed(1))
        );
        std::env::set_var("DCE_CHAOS", "utter-nonsense");
        assert_eq!(ChaosSpec::from_env(), None, "junk degrades to no chaos");
        std::env::set_var("DCE_CHAOS", "off");
        assert_eq!(ChaosSpec::from_env(), None);
        match saved {
            Some(v) => std::env::set_var("DCE_CHAOS", v),
            None => std::env::remove_var("DCE_CHAOS"),
        }
    }
}

//! Framed TCP transport: a full peer mesh of sockets speaking the
//! serving tier's wire discipline — every message is a 40-byte
//! [`FrameHeader`] plus packed payload, parsed through the same
//! hostile-input caps as `coordinator::server`, with read timeouts
//! instead of unbounded blocking.
//!
//! Mesh formation uses the classic rank convention: rank `i` dials
//! every lower rank and accepts from every higher rank, then identifies
//! itself with a hello frame (`req_id = u64::MAX`, `tenant = rank`).
//! One stream serves each unordered pair; kernel FIFO plus the
//! round-synchronous schedule keeps frames in order, and anything
//! mis-sequenced is a typed [`TransportError::OutOfOrder`] rejection.
//!
//! Peer frames reuse the header fields as: `tenant` = source rank,
//! `req_id` = `(round << 32) | port`, with the reserved port
//! [`BARRIER_PORT`] marking empty round-barrier frames.

use super::shmem::check_peer_frame;
use super::{Transport, TransportError};
use crate::gf::kernels::SymbolLayout;
use crate::net::payload::{
    decode_rows_frame, encode_rows_frame, FrameHeader, FrameKind, Packet, FRAME_HEADER_LEN,
};
use crate::net::sim::ProcId;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// The port number reserved for round-barrier frames (no payload).
pub const BARRIER_PORT: u32 = 0xFFFF_FFFF;

/// The `req_id` of the mesh-formation hello frame.
const HELLO_REQ_ID: u64 = u64::MAX;

fn peer_req_id(round: u32, port: u32) -> u64 {
    ((round as u64) << 32) | port as u64
}

fn map_io(e: std::io::Error, round: u32, peer: ProcId, timeout: Duration) -> TransportError {
    use std::io::ErrorKind::*;
    match e.kind() {
        WouldBlock | TimedOut => TransportError::Timeout {
            round,
            peer,
            waited: timeout,
        },
        UnexpectedEof | ConnectionReset | ConnectionAborted | BrokenPipe => {
            TransportError::PeerClosed { round, peer }
        }
        _ => TransportError::Io(e),
    }
}

/// Read one complete frame — header (parsed through the serving tier's
/// hostile caps) and payload — from `stream`, blocking at most
/// `timeout`. This is the exact code path [`TcpTransport::recv`] uses;
/// it is public so the conformance suite can aim raw hostile bytes at
/// it.
pub fn read_frame_from(
    stream: &mut TcpStream,
    peer: ProcId,
    round: u32,
    timeout: Duration,
) -> Result<(FrameHeader, Vec<u8>), TransportError> {
    stream.set_read_timeout(Some(timeout))?;
    let mut head = [0u8; FRAME_HEADER_LEN];
    stream
        .read_exact(&mut head)
        .map_err(|e| map_io(e, round, peer, timeout))?;
    let header = FrameHeader::parse(&head).map_err(|e| TransportError::Frame {
        peer,
        detail: format!("{e:#}"),
    })?;
    let mut payload = vec![0u8; header.payload_len as usize];
    stream
        .read_exact(&mut payload)
        .map_err(|e| map_io(e, round, peer, timeout))?;
    Ok((header, payload))
}

/// One rank's endpoint of a TCP mesh.
pub struct TcpTransport {
    rank: ProcId,
    procs: Vec<ProcId>,
    streams: HashMap<ProcId, TcpStream>,
    timeout: Duration,
    scratch: Vec<u8>,
    /// In-progress barrier state, so a timed-out [`Transport::barrier`]
    /// can be *retried* without poisoning the mesh: which round the
    /// barrier frames were sent for, which peers still need ours, and
    /// which peers we still owe a collect from. Without this, a retry
    /// would re-send to everyone (duplicate frames the peers reject as
    /// `OutOfOrder` next round) and re-collect from peers already
    /// counted (a permanent wedge).
    barrier_sent: Option<u32>,
    barrier_send_pending: Vec<ProcId>,
    barrier_recv_pending: Vec<ProcId>,
}

impl TcpTransport {
    /// Form this rank's endpoint of a full mesh: dial every rank below
    /// `rank` at its address (retrying until `timeout`, so processes
    /// may start in any order), accept every rank above from
    /// `listener`, and exchange hello frames. `addrs` must map every
    /// participant; `listener` must be bound at `addrs[rank]`.
    ///
    /// This is the real multi-process entry point —
    /// `examples/peer_encode.rs` gives each forked process a rank and
    /// the shared address table.
    pub fn connect(
        rank: ProcId,
        listener: TcpListener,
        addrs: &[(ProcId, SocketAddr)],
        timeout: Duration,
    ) -> anyhow::Result<TcpTransport> {
        let deadline = Instant::now() + timeout;
        let mut procs: Vec<ProcId> = addrs.iter().map(|&(p, _)| p).collect();
        procs.sort_unstable();
        anyhow::ensure!(
            procs.contains(&rank),
            "rank {rank} is not in the address table"
        );
        let mut streams = HashMap::new();
        // Dial down...
        for &(peer, addr) in addrs {
            if peer >= rank {
                continue;
            }
            let stream = loop {
                match TcpStream::connect(addr) {
                    Ok(s) => break s,
                    Err(e) => {
                        anyhow::ensure!(
                            Instant::now() < deadline,
                            "connecting to rank {peer} at {addr} timed out: {e}"
                        );
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            };
            stream.set_nodelay(true)?;
            let mut hello = Vec::new();
            encode_rows_frame(
                &mut hello,
                FrameKind::Request,
                SymbolLayout::U64,
                rank as u64,
                HELLO_REQ_ID,
                &[],
            )?;
            let mut stream = stream;
            stream.write_all(&hello)?;
            streams.insert(peer, stream);
        }
        // ...accept up. `accept` has no native timeout, so poll
        // nonblocking against the same deadline as the dial side.
        let expect_above = procs.iter().filter(|&&p| p > rank).count();
        listener.set_nonblocking(true)?;
        for _ in 0..expect_above {
            let (mut stream, _) = loop {
                match listener.accept() {
                    Ok(conn) => break conn,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        anyhow::ensure!(
                            Instant::now() < deadline,
                            "mesh formation timed out accepting peers"
                        );
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => return Err(e.into()),
                }
            };
            stream.set_nonblocking(false)?;
            stream.set_nodelay(true)?;
            let (header, _payload) = read_frame_from(&mut stream, usize::MAX, 0, timeout)
                .map_err(|e| anyhow::anyhow!("mesh hello failed: {e}"))?;
            anyhow::ensure!(
                header.req_id == HELLO_REQ_ID,
                "expected a hello frame, got req_id {:#x}",
                header.req_id
            );
            let peer = header.tenant as ProcId;
            anyhow::ensure!(
                procs.contains(&peer) && peer > rank,
                "unexpected hello from rank {peer}"
            );
            anyhow::ensure!(
                !streams.contains_key(&peer),
                "duplicate hello from rank {peer}"
            );
            streams.insert(peer, stream);
        }
        Ok(TcpTransport {
            rank,
            procs,
            streams,
            timeout,
            scratch: Vec::new(),
            barrier_sent: None,
            barrier_send_pending: Vec::new(),
            barrier_recv_pending: Vec::new(),
        })
    }

    /// Build a whole mesh over loopback for in-process tests: bind one
    /// ephemeral listener per rank, then form all endpoints on threads
    /// (the dial/accept handshake requires every rank to make
    /// progress concurrently). Endpoints return in `procs` order.
    pub fn loopback_mesh(
        procs: &[ProcId],
        timeout: Duration,
    ) -> anyhow::Result<Vec<TcpTransport>> {
        let mut listeners = Vec::new();
        let mut addrs = Vec::new();
        for &p in procs {
            let l = TcpListener::bind("127.0.0.1:0")?;
            addrs.push((p, l.local_addr()?));
            listeners.push(l);
        }
        let results: Vec<anyhow::Result<TcpTransport>> = std::thread::scope(|s| {
            let handles: Vec<_> = procs
                .iter()
                .zip(listeners)
                .map(|(&rank, listener)| {
                    let addrs = &addrs;
                    s.spawn(move || TcpTransport::connect(rank, listener, addrs, timeout))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("mesh thread panicked"))
                .collect()
        });
        results.into_iter().collect()
    }

    fn stream(&mut self, peer: ProcId, round: u32) -> Result<&mut TcpStream, TransportError> {
        self.streams
            .get_mut(&peer)
            .ok_or(TransportError::PeerClosed { round, peer })
    }

    fn send_frame(
        &mut self,
        round: u32,
        port: u32,
        dst: ProcId,
        rows: &[Packet],
    ) -> Result<(), TransportError> {
        self.scratch.clear();
        let mut scratch = std::mem::take(&mut self.scratch);
        let res = encode_rows_frame(
            &mut scratch,
            FrameKind::Request,
            SymbolLayout::U64,
            self.rank as u64,
            peer_req_id(round, port),
            rows,
        );
        let timeout = self.timeout;
        let out = match res {
            Ok(()) => {
                let stream = self.stream(dst, round)?;
                stream.set_write_timeout(Some(timeout))?;
                stream
                    .write_all(&scratch)
                    .map_err(|e| map_io(e, round, dst, timeout))
            }
            Err(e) => Err(TransportError::Frame {
                peer: dst,
                detail: format!("{e:#}"),
            }),
        };
        self.scratch = scratch;
        out
    }
}

impl Transport for TcpTransport {
    fn rank(&self) -> ProcId {
        self.rank
    }

    fn peers(&self) -> &[ProcId] {
        &self.procs
    }

    fn send(
        &mut self,
        round: u32,
        port: u32,
        dst: ProcId,
        rows: &[Packet],
    ) -> Result<(), TransportError> {
        self.send_frame(round, port, dst, rows)
    }

    fn recv(&mut self, round: u32, port: u32, src: ProcId) -> Result<Vec<Packet>, TransportError> {
        let timeout = self.timeout;
        let stream = self.stream(src, round)?;
        let (header, payload) = read_frame_from(stream, src, round, timeout)?;
        check_peer_frame(&header, round, port, src)?;
        decode_rows_frame(&header, &payload).map_err(|e| TransportError::Frame {
            peer: src,
            detail: format!("{e:#}"),
        })
    }

    /// The TCP barrier is message-based (there is no shared memory to
    /// count arrivals in): ship an empty barrier frame to every peer,
    /// then collect one from each. A peer that died mid-round surfaces
    /// as `PeerClosed`/`Timeout` here, bounded by the recv timeout.
    ///
    /// The barrier is **retry-idempotent**: on failure the send/collect
    /// progress for `round` is kept, so a retry resumes where it
    /// stopped — no peer is sent a duplicate frame, no peer is
    /// collected twice. This is what lets the hardened executor treat
    /// a barrier timeout as transient on TCP, just like on the
    /// `LocalBarrier` substrates.
    fn barrier(&mut self, round: u32) -> Result<(), TransportError> {
        if self.barrier_sent != Some(round) {
            let peers: Vec<ProcId> = self
                .procs
                .iter()
                .copied()
                .filter(|&p| p != self.rank)
                .collect();
            self.barrier_sent = Some(round);
            self.barrier_send_pending = peers.clone();
            self.barrier_recv_pending = peers;
        }
        while let Some(p) = self.barrier_send_pending.first().copied() {
            self.send_frame(round, BARRIER_PORT, p, &[])?;
            self.barrier_send_pending.remove(0);
        }
        let timeout = self.timeout;
        while let Some(p) = self.barrier_recv_pending.first().copied() {
            let stream = self.stream(p, round)?;
            let (header, _payload) = read_frame_from(stream, p, round, timeout)?;
            check_peer_frame(&header, round, BARRIER_PORT, p)?;
            self.barrier_recv_pending.remove(0);
        }
        self.barrier_sent = None;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_roundtrip_and_barrier() {
        let mesh = TcpTransport::loopback_mesh(&[0, 1, 2], Duration::from_secs(5)).unwrap();
        let results: Vec<Vec<Packet>> = std::thread::scope(|s| {
            let handles: Vec<_> = mesh
                .into_iter()
                .map(|mut t| {
                    s.spawn(move || {
                        let rank = t.rank();
                        // Ring: each rank sends to (rank+1) % 3.
                        let dst = (rank + 1) % 3;
                        let src = (rank + 2) % 3;
                        t.send(0, 0, dst, &[vec![rank as u64, 42]]).unwrap();
                        let got = t.recv(0, 0, src).unwrap();
                        t.barrier(0).unwrap();
                        got
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(results[0], vec![vec![2, 42]]);
        assert_eq!(results[1], vec![vec![0, 42]]);
        assert_eq!(results[2], vec![vec![1, 42]]);
    }

    #[test]
    fn hostile_header_is_rejected_by_caps() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let attacker = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // A header promising 2^30 rows — the serving tier's caps
            // must reject it before any allocation happens.
            let mut buf = Vec::new();
            buf.extend_from_slice(b"DCE1");
            buf.push(2); // Request
            buf.push(8); // u64 lane
            buf.extend_from_slice(&[0; 2]);
            buf.extend_from_slice(&0u64.to_le_bytes()); // tenant
            buf.extend_from_slice(&0u64.to_le_bytes()); // req_id
            buf.extend_from_slice(&(1u32 << 30).to_le_bytes()); // rows
            buf.extend_from_slice(&1u32.to_le_bytes()); // width
            buf.extend_from_slice(&8u32.to_le_bytes()); // payload_len
            buf.extend_from_slice(&[0; 4]);
            s.write_all(&buf).unwrap();
            s
        });
        let (mut server_side, _) = listener.accept().unwrap();
        let err = read_frame_from(&mut server_side, 0, 0, Duration::from_secs(2)).unwrap_err();
        match err {
            TransportError::Frame { detail, .. } => {
                assert!(detail.contains("too large"), "unexpected detail: {detail}")
            }
            other => panic!("expected Frame error, got {other:?}"),
        }
        drop(attacker.join().unwrap());
    }
}

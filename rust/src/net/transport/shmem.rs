//! Shared-memory transport: one single-producer/single-consumer byte
//! ring per directed peer pair, carrying exactly the wire frames the
//! TCP transport ships (40-byte [`FrameHeader`] + packed payload).
//!
//! The ring is the classic lock-free SPSC design: one fixed buffer, a
//! monotonic write cursor (`head`) owned by the producer and a monotonic
//! read cursor (`tail`) owned by the consumer, each published with
//! `Release` and observed with `Acquire` so the byte copies are ordered
//! against the cursor updates. Exactly one thread writes and exactly one
//! reads per ring (the mesh hands each endpoint only its own sides), so
//! no CAS or lock is ever needed. A `closed` flag set when the producing
//! endpoint drops turns "peer died" into a typed
//! [`TransportError::PeerClosed`] instead of a stuck consumer.

use super::{LocalBarrier, Transport, TransportError};
use crate::gf::kernels::SymbolLayout;
use crate::net::payload::{
    decode_rows_frame, encode_rows_frame, FrameHeader, FrameKind, Packet, FRAME_HEADER_LEN,
};
use crate::net::sim::ProcId;
use std::cell::UnsafeCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One directed SPSC byte ring. `head`/`tail` are monotonic byte
/// counts; the buffer index is `pos % cap`.
struct Ring {
    buf: UnsafeCell<Box<[u8]>>,
    cap: usize,
    head: AtomicUsize,
    tail: AtomicUsize,
    closed: AtomicBool,
}

// SAFETY: the mesh constructor gives the producing endpoint exclusive
// write access and the consuming endpoint exclusive read access; the
// byte ranges they touch are disjoint ([tail, head) is consumer-owned,
// [head, tail + cap) producer-owned) and handed over by Release/Acquire
// on the cursors.
unsafe impl Sync for Ring {}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring {
            buf: UnsafeCell::new(vec![0u8; cap].into_boxed_slice()),
            cap,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
        }
    }

    /// Producer side: append `bytes`, waiting for space until `deadline`.
    fn push(&self, bytes: &[u8], deadline: Instant) -> Result<(), PushErr> {
        if bytes.len() > self.cap {
            return Err(PushErr::Overflow {
                need: bytes.len(),
                capacity: self.cap,
            });
        }
        let head = self.head.load(Ordering::Relaxed);
        loop {
            let tail = self.tail.load(Ordering::Acquire);
            if head - tail + bytes.len() <= self.cap {
                break;
            }
            if self.closed.load(Ordering::Acquire) {
                return Err(PushErr::Closed);
            }
            if Instant::now() >= deadline {
                return Err(PushErr::Timeout);
            }
            std::thread::yield_now();
        }
        let at = head % self.cap;
        let first = bytes.len().min(self.cap - at);
        // SAFETY: sole producer; [head, head + len) is unpublished space
        // the consumer cannot read until the Release store below.
        unsafe {
            let buf = &mut *self.buf.get();
            buf[at..at + first].copy_from_slice(&bytes[..first]);
            buf[..bytes.len() - first].copy_from_slice(&bytes[first..]);
        }
        self.head.store(head + bytes.len(), Ordering::Release);
        Ok(())
    }

    /// Consumer side: read exactly `len` bytes, waiting until `deadline`.
    fn pop_exact(&self, len: usize, deadline: Instant) -> Result<Vec<u8>, PopErr> {
        if len > self.cap {
            return Err(PopErr::Overflow {
                need: len,
                capacity: self.cap,
            });
        }
        let tail = self.tail.load(Ordering::Relaxed);
        loop {
            let head = self.head.load(Ordering::Acquire);
            if head - tail >= len {
                break;
            }
            if self.closed.load(Ordering::Acquire) {
                // Re-check after the closed flag: the producer publishes
                // head before closing, so a final frame is never lost.
                if self.head.load(Ordering::Acquire) - tail < len {
                    return Err(PopErr::Closed);
                }
                break;
            }
            if Instant::now() >= deadline {
                return Err(PopErr::Timeout);
            }
            std::thread::yield_now();
        }
        let at = tail % self.cap;
        let first = len.min(self.cap - at);
        let mut out = vec![0u8; len];
        // SAFETY: sole consumer; [tail, tail + len) was published by the
        // producer's Release store and is not rewritten until we bump
        // tail below.
        unsafe {
            let buf = &*self.buf.get();
            out[..first].copy_from_slice(&buf[at..at + first]);
            out[first..].copy_from_slice(&buf[..len - first]);
        }
        self.tail.store(tail + len, Ordering::Release);
        Ok(out)
    }
}

enum PushErr {
    Timeout,
    Closed,
    Overflow { need: usize, capacity: usize },
}

enum PopErr {
    Timeout,
    Closed,
    Overflow { need: usize, capacity: usize },
}

/// One rank's endpoint of a shared-memory mesh built by
/// [`ShmemTransport::mesh`].
pub struct ShmemTransport {
    rank: ProcId,
    procs: Vec<ProcId>,
    /// Rings this endpoint produces into, by destination.
    out: HashMap<ProcId, Arc<Ring>>,
    /// Rings this endpoint consumes from, by source.
    inn: HashMap<ProcId, Arc<Ring>>,
    barrier: Arc<LocalBarrier>,
    timeout: Duration,
    scratch: Vec<u8>,
}

impl ShmemTransport {
    /// Build a full mesh over `procs`. Each directed pair gets a ring
    /// sized to hold `ports` maximal frames (`max_msg_bytes` payload
    /// bytes each) twice over, so one round of traffic never stalls the
    /// producer; `timeout` bounds every wait.
    pub fn mesh(
        procs: &[ProcId],
        ports: usize,
        max_msg_bytes: usize,
        timeout: Duration,
    ) -> Vec<ShmemTransport> {
        let frame = FRAME_HEADER_LEN + max_msg_bytes;
        let cap = (2 * ports.max(1) * frame).max(4096);
        let barrier = Arc::new(LocalBarrier::new(procs));
        let mut rings: HashMap<(ProcId, ProcId), Arc<Ring>> = HashMap::new();
        for &src in procs {
            for &dst in procs {
                if src != dst {
                    rings.insert((src, dst), Arc::new(Ring::new(cap)));
                }
            }
        }
        procs
            .iter()
            .map(|&rank| ShmemTransport {
                rank,
                procs: procs.to_vec(),
                out: procs
                    .iter()
                    .filter(|&&p| p != rank)
                    .map(|&p| (p, rings[&(rank, p)].clone()))
                    .collect(),
                inn: procs
                    .iter()
                    .filter(|&&p| p != rank)
                    .map(|&p| (p, rings[&(p, rank)].clone()))
                    .collect(),
                barrier: barrier.clone(),
                timeout,
                scratch: Vec::new(),
            })
            .collect()
    }

    fn deadline(&self) -> Instant {
        Instant::now() + self.timeout
    }
}

impl Drop for ShmemTransport {
    fn drop(&mut self) {
        // Mark both sides: consumers of our rings learn no more bytes
        // come, producers into us learn nobody will drain them — a dead
        // peer becomes a typed PeerClosed, not a stuck spin.
        for ring in self.out.values().chain(self.inn.values()) {
            ring.closed.store(true, Ordering::Release);
        }
    }
}

/// Peer messages ride the serving tier's frame format: `tenant` carries
/// the source rank and `req_id` packs `(round << 32) | port`, so the
/// consumer can verify round discipline from the header alone.
fn peer_req_id(round: u32, port: u32) -> u64 {
    ((round as u64) << 32) | port as u64
}

impl Transport for ShmemTransport {
    fn rank(&self) -> ProcId {
        self.rank
    }

    fn peers(&self) -> &[ProcId] {
        &self.procs
    }

    fn send(
        &mut self,
        round: u32,
        port: u32,
        dst: ProcId,
        rows: &[Packet],
    ) -> Result<(), TransportError> {
        let ring = self
            .out
            .get(&dst)
            .cloned()
            .ok_or(TransportError::PeerClosed { round, peer: dst })?;
        self.scratch.clear();
        encode_rows_frame(
            &mut self.scratch,
            FrameKind::Request,
            SymbolLayout::U64,
            self.rank as u64,
            peer_req_id(round, port),
            rows,
        )
        .map_err(|e| TransportError::Frame {
            peer: dst,
            detail: format!("{e:#}"),
        })?;
        match ring.push(&self.scratch, self.deadline()) {
            Ok(()) => Ok(()),
            Err(PushErr::Timeout) => Err(TransportError::Timeout {
                round,
                peer: dst,
                waited: self.timeout,
            }),
            Err(PushErr::Closed) => Err(TransportError::PeerClosed { round, peer: dst }),
            Err(PushErr::Overflow { need, capacity }) => {
                Err(TransportError::RingOverflow { need, capacity })
            }
        }
    }

    fn recv(&mut self, round: u32, port: u32, src: ProcId) -> Result<Vec<Packet>, TransportError> {
        let ring = self
            .inn
            .get(&src)
            .cloned()
            .ok_or(TransportError::PeerClosed { round, peer: src })?;
        let deadline = self.deadline();
        let map_pop = |e: PopErr| match e {
            PopErr::Timeout => TransportError::Timeout {
                round,
                peer: src,
                waited: self.timeout,
            },
            PopErr::Closed => TransportError::PeerClosed { round, peer: src },
            PopErr::Overflow { need, capacity } => TransportError::RingOverflow { need, capacity },
        };
        let head_bytes = ring.pop_exact(FRAME_HEADER_LEN, deadline).map_err(map_pop)?;
        let head_arr: &[u8; FRAME_HEADER_LEN] =
            head_bytes.as_slice().try_into().expect("exact header read");
        let header = FrameHeader::parse(head_arr).map_err(|e| TransportError::Frame {
            peer: src,
            detail: format!("{e:#}"),
        })?;
        let payload = ring
            .pop_exact(header.payload_len as usize, deadline)
            .map_err(map_pop)?;
        check_peer_frame(&header, round, port, src)?;
        decode_rows_frame(&header, &payload).map_err(|e| TransportError::Frame {
            peer: src,
            detail: format!("{e:#}"),
        })
    }

    fn barrier(&mut self, round: u32) -> Result<(), TransportError> {
        self.barrier.wait(self.rank, self.timeout).map_err(|miss| {
            // Blame the first rank that had not arrived when we gave up.
            let peer = miss.missing.first().copied().unwrap_or(self.rank);
            TransportError::Timeout {
                round,
                peer,
                waited: miss.waited,
            }
        })
    }
}

/// Shared header validation for the framed transports: right source,
/// right round, right port.
pub(super) fn check_peer_frame(
    header: &FrameHeader,
    round: u32,
    port: u32,
    src: ProcId,
) -> Result<(), TransportError> {
    if header.tenant != src as u64 {
        return Err(TransportError::Frame {
            peer: src,
            detail: format!("frame claims source rank {}, stream is from {src}", header.tenant),
        });
    }
    let got_round = (header.req_id >> 32) as u32;
    let got_port = header.req_id as u32;
    if got_round != round {
        return Err(TransportError::OutOfOrder {
            peer: src,
            expected_round: round,
            got_round,
        });
    }
    if got_port != port {
        return Err(TransportError::PortMismatch {
            peer: src,
            round,
            expected_port: port,
            got_port,
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_roundtrip_with_wraparound() {
        let ring = Ring::new(64);
        let deadline = Instant::now() + Duration::from_secs(1);
        for i in 0..50u8 {
            let msg: Vec<u8> = (0..13).map(|j| i.wrapping_mul(7).wrapping_add(j)).collect();
            ring.push(&msg, deadline).map_err(|_| "push").unwrap();
            let got = ring.pop_exact(13, deadline).map_err(|_| "pop").unwrap();
            assert_eq!(got, msg);
        }
    }

    #[test]
    fn roundtrip_two_ranks() {
        let mut mesh = ShmemTransport::mesh(&[0, 1], 1, 1 << 12, Duration::from_secs(2));
        let mut t1 = mesh.pop().unwrap();
        let mut t0 = mesh.pop().unwrap();
        std::thread::scope(|s| {
            s.spawn(move || {
                t0.send(3, 1, 1, &[vec![5, 6, 7], vec![8, 9, 10]]).unwrap();
                t0.barrier(3).unwrap();
            });
            s.spawn(move || {
                let rows = t1.recv(3, 1, 0).unwrap();
                assert_eq!(rows, vec![vec![5, 6, 7], vec![8, 9, 10]]);
                t1.barrier(3).unwrap();
            });
        });
    }

    #[test]
    fn dropped_peer_is_typed_not_a_hang() {
        let mut mesh = ShmemTransport::mesh(&[0, 1], 1, 1 << 12, Duration::from_millis(200));
        let t1 = mesh.pop().unwrap();
        let mut t0 = mesh.pop().unwrap();
        drop(t1);
        match t0.recv(0, 0, 1) {
            Err(TransportError::PeerClosed { peer: 1, .. }) => {}
            other => panic!("expected PeerClosed, got {other:?}"),
        }
    }

    #[test]
    fn oversized_message_is_ring_overflow() {
        let mut mesh = ShmemTransport::mesh(&[0, 1], 1, 16, Duration::from_millis(200));
        let _t1 = mesh.pop().unwrap();
        let mut t0 = mesh.pop().unwrap();
        let huge: Packet = vec![1; 1 << 12];
        match t0.send(0, 0, 1, &[huge]) {
            Err(TransportError::RingOverflow { .. }) => {}
            other => panic!("expected RingOverflow, got {other:?}"),
        }
    }
}

//! The linear communication-cost model (Fraigniaud & Lazard \[16\]).

/// Cost model `C = α·C1 + β·⌈log2 q⌉·C2`.
///
/// * `alpha` — per-round start-up time (latency),
/// * `beta` — per-bit transfer time (inverse bandwidth),
/// * `q_bits` — `⌈log2 q⌉`, bits per field element on the wire.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    pub alpha: f64,
    pub beta: f64,
    pub q_bits: u32,
}

impl CostModel {
    pub fn new(alpha: f64, beta: f64, q_bits: u32) -> Self {
        CostModel {
            alpha,
            beta,
            q_bits,
        }
    }

    /// Total cost of a run with the given round/element counts.
    pub fn cost(&self, c1: u64, c2: u64) -> f64 {
        self.alpha * c1 as f64 + self.beta * self.q_bits as f64 * c2 as f64
    }

    /// A latency-dominated regime (large α/β ratio).
    pub fn latency_bound(q_bits: u32) -> Self {
        CostModel::new(1000.0, 0.01, q_bits)
    }

    /// A bandwidth-dominated regime (small α/β ratio).
    pub fn bandwidth_bound(q_bits: u32) -> Self {
        CostModel::new(1.0, 1.0, q_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_is_linear() {
        let m = CostModel::new(10.0, 2.0, 20);
        assert_eq!(m.cost(0, 0), 0.0);
        assert_eq!(m.cost(3, 5), 10.0 * 3.0 + 2.0 * 20.0 * 5.0);
    }
}

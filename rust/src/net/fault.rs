//! Fault injection: crash-stop processors, dropped links, per-round
//! erasures — and the **taint closure** that says exactly which outputs
//! survive a degraded run.
//!
//! The paper's entire reason for encoding with an MDS generator (§II,
//! §V–§VI) is that the system tolerates processor loss: any `K` of the
//! `N = K + R` codeword coordinates determine the data. This module
//! supplies the failure half of that story for both execution engines:
//!
//! * a [`FaultSpec`] describes *what fails* — crash-stop processors
//!   (dead from a given round on; `round = POST_RUN` models storage loss
//!   after a completed run), dropped directed links, and per-round
//!   erasure sets — with seeded deterministic injection for tests and
//!   benches;
//! * [`analyze_plan`] / the engine-side tracker compute *what that
//!   implies*: a message is dropped when its sender or receiver is dead
//!   or its link/round is erased, a processor that misses an expected
//!   message is **tainted**, and taint propagates along every later
//!   delivery out of a tainted sender. The closure is conservative and
//!   exact for the crash-stop model: an untainted, alive processor saw
//!   *precisely* the inbox sequence of the healthy run, so its outputs
//!   are bit-identical to the healthy run's — the property
//!   `tests/fault_recovery.rs` asserts across every algorithm.
//!
//! Because every schedule in this codebase is shape-determined
//! (Remark 1: who sends what to whom never depends on payload data —
//! tainted processors keep the schedule and send garbage), the same
//! analysis applies to a live [`run_degraded`](crate::net::run_degraded)
//! and to a compiled [`Plan`](crate::net::plan::Plan) walk, and the two
//! produce identical [`DegradedReport`]s.

use super::plan::Plan;
use super::sim::{ProcId, SimReport};
use crate::util::Rng;
use std::collections::{BTreeMap, BTreeSet};

/// Crash round modelling a processor lost *after* the run completed
/// (the distributed-storage scenario: the node encoded and replied, then
/// its disk died). No message is ever dropped; the output is lost.
pub const POST_RUN: u64 = u64::MAX;

/// A deterministic description of which processors, links and rounds
/// fail. Builder-style; all constructors are order-insensitive.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultSpec {
    /// `pid → first dead round` (1-based): the processor neither sends
    /// nor receives from that round on, and its output is lost.
    crashes: BTreeMap<ProcId, u64>,
    /// Directed links dropped in every round.
    links: BTreeSet<(ProcId, ProcId)>,
    /// Single-round erasures `(round, src, dst)`.
    erasures: BTreeSet<(u64, ProcId, ProcId)>,
}

impl FaultSpec {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty() && self.links.is_empty() && self.erasures.is_empty()
    }

    /// Number of injected fault directives (crashes + links + erasures)
    /// — the `faults_injected` metric.
    pub fn injected(&self) -> u64 {
        (self.crashes.len() + self.links.len() + self.erasures.len()) as u64
    }

    /// Crash-stop `pid` before it ever sends (dead from round 1).
    pub fn crash(self, pid: ProcId) -> Self {
        self.crash_from(pid, 1)
    }

    /// Crash-stop `pid` from `round` (1-based) on: rounds `< round` are
    /// healthy, everything later is dead. An earlier crash wins.
    pub fn crash_from(mut self, pid: ProcId, round: u64) -> Self {
        assert!(round >= 1, "rounds are 1-based");
        let e = self.crashes.entry(pid).or_insert(round);
        *e = (*e).min(round);
        self
    }

    /// Lose `pid` *after* the run completed (no messages dropped, output
    /// lost) — see [`POST_RUN`].
    pub fn crash_after(self, pid: ProcId) -> Self {
        self.crash_from(pid, POST_RUN)
    }

    /// Drop every message `src → dst` (directed), in every round.
    pub fn drop_link(mut self, src: ProcId, dst: ProcId) -> Self {
        self.links.insert((src, dst));
        self
    }

    /// Erase the messages `src → dst` of one specific round.
    pub fn erase(mut self, round: u64, src: ProcId, dst: ProcId) -> Self {
        self.erasures.insert((round, src, dst));
        self
    }

    /// Seeded deterministic injection: crash `n` distinct processors
    /// drawn from `candidates`, all from `round` on (pass [`POST_RUN`]
    /// for the storage-loss scenario). `n > candidates.len()` crashes
    /// them all.
    pub fn random_crashes(seed: u64, candidates: &[ProcId], n: usize, round: u64) -> Self {
        let mut rng = Rng::new(seed);
        let picks = rng.choose(candidates.len(), n.min(candidates.len()));
        picks
            .into_iter()
            .fold(FaultSpec::new(), |s, i| s.crash_from(candidates[i], round))
    }

    /// Processors named by a crash directive (any round).
    pub fn crashed_procs(&self) -> Vec<ProcId> {
        self.crashes.keys().copied().collect()
    }

    /// Is `pid` dead in round `round`?
    pub fn crashed_by(&self, pid: ProcId, round: u64) -> bool {
        self.crashes.get(&pid).is_some_and(|&r| round >= r)
    }

    /// Is `pid` crashed at all (its output is lost even if every round
    /// ran healthily, e.g. a [`POST_RUN`] loss)?
    pub fn is_crashed(&self, pid: ProcId) -> bool {
        self.crashes.contains_key(&pid)
    }

    pub(crate) fn link_or_erasure(&self, round: u64, src: ProcId, dst: ProcId) -> bool {
        self.links.contains(&(src, dst)) || self.erasures.contains(&(round, src, dst))
    }

    /// Crash directives as `(pid, first dead round)` pairs (1-based) —
    /// the chaos layer mirrors them onto the wire.
    pub(crate) fn crash_entries(&self) -> impl Iterator<Item = (ProcId, u64)> + '_ {
        self.crashes.iter().map(|(&p, &r)| (p, r))
    }

    /// Dropped directed links, ascending.
    pub(crate) fn link_entries(&self) -> impl Iterator<Item = (ProcId, ProcId)> + '_ {
        self.links.iter().copied()
    }

    /// Single-round erasures `(round, src, dst)`, ascending.
    pub(crate) fn erasure_entries(&self) -> impl Iterator<Item = (u64, ProcId, ProcId)> + '_ {
        self.erasures.iter().copied()
    }
}

/// What a degraded run did and who survived it. Produced identically by
/// the live engine ([`run_degraded`](crate::net::run_degraded)) and the
/// plan walk ([`analyze_plan`]) — `tests/fault_recovery.rs` asserts the
/// equality.
#[derive(Clone, Debug, PartialEq)]
pub struct DegradedReport {
    /// Traffic actually delivered (`C1` still counts every scheduled
    /// round — wall-clock rounds elapse whether or not their messages
    /// arrive; `m_t`, `C2`, `messages`, `bandwidth` count survivors
    /// only).
    pub delivered: SimReport,
    pub dropped_messages: u64,
    /// Field elements dropped (the erased-traffic counterpart of
    /// `bandwidth`).
    pub dropped_elems: u64,
    /// Processors named by a crash directive: their outputs are lost and
    /// — crucially — so is their *input data* (a dead node holds
    /// nothing).
    pub crashed: BTreeSet<ProcId>,
    /// Alive processors whose computed state diverged (missed a message,
    /// or consumed one computed from divergent state). Their *outputs*
    /// are garbage, but they still hold their own input data.
    pub tainted: BTreeSet<ProcId>,
}

impl DegradedReport {
    /// Did `pid`'s *output* survive (alive and untainted — guaranteed
    /// bit-identical to the healthy run)?
    pub fn survives(&self, pid: ProcId) -> bool {
        !self.crashed.contains(&pid) && !self.tainted.contains(&pid)
    }

    /// Does `pid` still hold its own *input* packet? Taint corrupts
    /// computed state, not the initial holding; only death loses it.
    pub fn holds_data(&self, pid: ProcId) -> bool {
        !self.crashed.contains(&pid)
    }

    /// All processors whose outputs are lost (crashed ∪ tainted).
    pub fn lost(&self) -> BTreeSet<ProcId> {
        self.crashed.union(&self.tainted).copied().collect()
    }
}

/// The shared taint-closure state machine: both engines feed it every
/// scheduled message in round order and route only what it admits.
pub(crate) struct FaultTracker<'a> {
    spec: &'a FaultSpec,
    /// `pid → round after whose absorption the state is wrong`; sends of
    /// any strictly later round propagate taint.
    taint_round: BTreeMap<ProcId, u64>,
    dropped_messages: u64,
    dropped_elems: u64,
}

impl<'a> FaultTracker<'a> {
    pub(crate) fn new(spec: &'a FaultSpec) -> Self {
        FaultTracker {
            spec,
            taint_round: BTreeMap::new(),
            dropped_messages: 0,
            dropped_elems: 0,
        }
    }

    /// Decide one scheduled message of round `t` (1-based). Returns
    /// `true` when it is delivered. Order-insensitive within a round:
    /// round-`t` sends were computed before round-`t` deliveries, so
    /// only taint acquired in rounds `< t` propagates.
    pub(crate) fn on_message(&mut self, t: u64, src: ProcId, dst: ProcId, elems: u64) -> bool {
        let dropped = self.spec.crashed_by(src, t)
            || self.spec.crashed_by(dst, t)
            || self.spec.link_or_erasure(t, src, dst);
        if dropped {
            self.dropped_messages += 1;
            self.dropped_elems += elems;
            if !self.spec.crashed_by(dst, t) {
                // The receiver is alive and missed an input.
                self.taint(dst, t);
            }
            return false;
        }
        if self.tainted_before(src, t) {
            // Delivered, but computed from divergent state.
            self.taint(dst, t);
        }
        true
    }

    fn tainted_before(&self, pid: ProcId, t: u64) -> bool {
        self.taint_round.get(&pid).is_some_and(|&t0| t0 < t)
    }

    fn taint(&mut self, pid: ProcId, t: u64) {
        let e = self.taint_round.entry(pid).or_insert(t);
        *e = (*e).min(t);
    }

    /// Seal the analysis with the delivered-traffic report.
    pub(crate) fn finish(self, delivered: SimReport) -> DegradedReport {
        DegradedReport {
            delivered,
            dropped_messages: self.dropped_messages,
            dropped_elems: self.dropped_elems,
            crashed: self.spec.crashes.keys().copied().collect(),
            tainted: self.taint_round.keys().copied().collect(),
        }
    }
}

/// Walk a compiled plan's schedule under `spec` at payload width `w`:
/// the exact [`DegradedReport`] a degraded *live* run of the same
/// collective records (the schedule is shape-determined, so the plan's
/// `SendOp`s are the live emissions verbatim).
pub fn analyze_plan(plan: &Plan, w: usize, spec: &FaultSpec) -> DegradedReport {
    let w = w as u64;
    let mut tracker = FaultTracker::new(spec);
    let mut delivered = SimReport::default();
    for (t, round) in plan.rounds().iter().enumerate() {
        let t = t as u64 + 1;
        let mut m_t = 0u64;
        for s in &round.sends {
            let elems = s.slots.len() as u64 * w;
            if tracker.on_message(t, s.src, s.dst, elems) {
                m_t = m_t.max(elems);
                delivered.messages += 1;
                delivered.bandwidth += elems;
            }
        }
        delivered.c1 += 1;
        delivered.c2 += m_t;
        delivered.per_round_max.push(m_t);
    }
    tracker.finish(delivered)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_drops_and_taints_nothing() {
        let spec = FaultSpec::new();
        assert!(spec.is_empty());
        assert_eq!(spec.injected(), 0);
        let mut tr = FaultTracker::new(&spec);
        assert!(tr.on_message(1, 0, 1, 3));
        assert!(tr.on_message(2, 1, 2, 3));
        let rep = tr.finish(SimReport::default());
        assert_eq!(rep.dropped_messages, 0);
        assert!(rep.crashed.is_empty() && rep.tainted.is_empty());
        assert!(rep.survives(0) && rep.survives(1) && rep.survives(2));
    }

    #[test]
    fn crash_drops_sends_from_its_round_on() {
        let spec = FaultSpec::new().crash_from(1, 2);
        let mut tr = FaultTracker::new(&spec);
        assert!(tr.on_message(1, 1, 2, 1), "round 1: still healthy");
        assert!(!tr.on_message(2, 1, 2, 1), "round 2: dead");
        assert!(!tr.on_message(3, 0, 1, 1), "dead receivers drop too");
        let rep = tr.finish(SimReport::default());
        assert_eq!(rep.dropped_messages, 2);
        assert!(rep.crashed.contains(&1));
        // 2 missed a round-2 input → tainted; 0's send to the dead 1
        // taints nobody.
        assert!(rep.tainted.contains(&2));
        assert!(!rep.tainted.contains(&0));
        assert!(!rep.holds_data(1) && rep.holds_data(2));
        assert!(!rep.survives(2) && rep.survives(0));
    }

    #[test]
    fn taint_propagates_only_through_later_deliveries() {
        let spec = FaultSpec::new().erase(1, 0, 1);
        let mut tr = FaultTracker::new(&spec);
        assert!(!tr.on_message(1, 0, 1, 5), "erased");
        // Same round: 1's sends were computed before the miss — clean.
        assert!(tr.on_message(1, 1, 2, 5));
        // Later round: 1's state is wrong, 3 inherits the taint.
        assert!(tr.on_message(2, 1, 3, 5));
        let rep = tr.finish(SimReport::default());
        assert_eq!(
            rep.tainted.iter().copied().collect::<Vec<_>>(),
            vec![1, 3]
        );
        assert_eq!(rep.dropped_elems, 5);
    }

    #[test]
    fn post_run_crash_never_drops() {
        let spec = FaultSpec::new().crash_after(7);
        let mut tr = FaultTracker::new(&spec);
        assert!(tr.on_message(1, 7, 0, 1));
        assert!(tr.on_message(9, 0, 7, 1));
        let rep = tr.finish(SimReport::default());
        assert_eq!(rep.dropped_messages, 0);
        assert!(rep.tainted.is_empty());
        assert!(!rep.survives(7) && !rep.holds_data(7), "output + data lost");
    }

    #[test]
    fn dropped_link_is_directed_and_earlier_crash_wins() {
        let spec = FaultSpec::new().drop_link(0, 1);
        let mut tr = FaultTracker::new(&spec);
        assert!(!tr.on_message(4, 0, 1, 1));
        assert!(tr.on_message(4, 1, 0, 1), "reverse direction intact");
        let spec = FaultSpec::new().crash_from(3, 5).crash_from(3, 2);
        assert!(spec.crashed_by(3, 2));
        assert!(!spec.crashed_by(3, 1));
    }

    #[test]
    fn random_crashes_are_deterministic_and_distinct() {
        let procs: Vec<ProcId> = (0..10).collect();
        let a = FaultSpec::random_crashes(42, &procs, 4, POST_RUN);
        let b = FaultSpec::random_crashes(42, &procs, 4, POST_RUN);
        assert_eq!(a, b);
        assert_eq!(a.crashed_procs().len(), 4);
        assert_eq!(a.injected(), 4);
        let c = FaultSpec::random_crashes(43, &procs, 20, 1);
        assert_eq!(c.crashed_procs().len(), 10, "capped at the candidates");
    }
}

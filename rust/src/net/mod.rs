//! The paper's communication model, executable.
//!
//! A fully-connected, homogeneous, **p-port** network operating in
//! synchronous rounds: in every round each processor may send one message
//! and receive one message through each of its `p` ports. Round `t` costs
//! `α + β·m_t` where `m_t` is the size (in `F_q` elements) of the largest
//! message in that round, so a full run costs
//!
//! ```text
//! C = α·C1 + β⌈log2 q⌉·C2,   C1 = #rounds,   C2 = Σ_t m_t.
//! ```
//!
//! [`sim::run`] executes a [`sim::Collective`] (an algorithm = scheduling
//! + coding scheme) against this model, *enforcing* the port constraints
//! and accounting `C1`/`C2` exactly as defined above.
//!
//! With the `parallel` cargo feature, collectives that fan out over
//! processors (notably [`Par`](crate::collectives::Par) and the
//! prepare-and-shoot hot loops) step with rayon; [`set_parallel`] toggles
//! this at runtime so sequential/parallel runs can be compared
//! bit-for-bit in one process.

pub mod exec;
pub mod fault;
pub mod model;
pub mod noisy;
pub mod opt;
pub mod payload;
pub mod peer;
pub mod plan;
pub mod shard;
pub mod sim;
pub mod trace;
pub mod transport;

pub use exec::{
    replay, replay_batch, replay_batch_kernels, replay_batch_ntt, replay_batch_scalar,
    replay_degraded, replay_degraded_batch, replay_degraded_batch_kernels, replay_full,
    replay_opt, DegradedReplay, Replay, WireReplay,
};
pub use fault::{analyze_plan, DegradedReport, FaultSpec, POST_RUN};
pub use model::CostModel;
pub use noisy::{ErasureChannel, InnerFec, NoisyCollective};
pub use opt::{
    optimize, select_backend, BackendKind, CodeShape, EncodeBackend, NttBackend, OptStats,
    OptimizedPlan, OutputMatrix, RowKind, NTT_DENSE_OP_RATIO,
};
pub use payload::{
    decode_rows_frame, encode_error_frame, encode_rows_frame, frame_error_message, lincomb,
    pkt_add, pkt_add_scaled, pkt_scale, pkt_zero, FrameHeader, FrameKind, Packet,
    PackedPacketBuf, PacketBuf, FRAME_HEADER_LEN, FRAME_MAGIC,
};
pub use peer::{
    execute_shard, merge_stats, run_peer, spawn_local, spawn_local_chaos, DegradedPeerRun,
    PeerRun, PeerStats, RetryPolicy, ShardedPlan,
};
pub use plan::{compile, ComputeOp, Plan, PlanRecorder, RoundPlan, SendOp, SlotId};
pub use shard::{LocalComb, LocalCompute, PlanShard, ShardRecv, ShardRound, ShardSend};
pub use sim::{run, run_degraded, Collective, DegradedRun, Msg, Outputs, ProcId, Sim, SimReport};
pub use trace::TraceEvent;
pub use transport::{ChaosSpec, ChaosTransport, Transport, TransportError, TransportKind};

#[cfg(feature = "parallel")]
static PARALLEL_DISABLED: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(false);

/// Whether parallel round steps are active. Always `false` without the
/// `parallel` cargo feature.
pub fn parallel_enabled() -> bool {
    #[cfg(feature = "parallel")]
    {
        !PARALLEL_DISABLED.load(std::sync::atomic::Ordering::Relaxed)
    }
    #[cfg(not(feature = "parallel"))]
    {
        false
    }
}

/// Toggle parallel round steps at runtime (no-op without the `parallel`
/// feature). Sequential and parallel execution are bit-identical by
/// construction; this exists so tests can assert exactly that.
pub fn set_parallel(enabled: bool) {
    #[cfg(feature = "parallel")]
    PARALLEL_DISABLED.store(!enabled, std::sync::atomic::Ordering::Relaxed);
    #[cfg(not(feature = "parallel"))]
    let _ = enabled;
}

//! Packet payloads: vectors in `F_q^W`, stored flat.
//!
//! Remark 2 of the paper: an A2A algorithm over `F_q` applies verbatim to
//! data vectors in `F_q^W` by viewing them as elements of the extension
//! field `F_{q^W}` while keeping the coding matrix over `F_q` — same `C1`,
//! `W×` the `C2`. A logical packet is therefore a `W`-vector of base field
//! elements charged as `W` elements on the wire.
//!
//! Two representations:
//!
//! * [`Packet`] — one owned logical packet (`Vec<u64>`), the currency of
//!   collective inputs/outputs;
//! * [`PacketBuf`] — a **width-aware flat buffer**: `count` packets of
//!   `width` elements each in one contiguous allocation, with
//!   slice-indexed views. Every wire message and every per-processor
//!   working set (prepare memories, shoot accumulators) uses this form,
//!   so the axpy/lincomb kernels run over contiguous memory instead of
//!   chasing one heap allocation per packet;
//! * [`PackedPacketBuf`] — the packed twin: the same flat shape but in
//!   narrow-lane storage (`u8`/`u16`/`u32` per the field's `⌈log2 q⌉`),
//!   the columnar-arena form the batched replay engine streams through
//!   the `gf::kernels` vtable.

use crate::gf::kernels::{PackedBuf, SymbolLayout};
use crate::gf::Field;

/// A single logical packet: `W` field elements (`W = 1` for the scalar
/// A2A of Def. 4).
pub type Packet = Vec<u64>;

/// A flat buffer of `count` packets, each `width` field elements, in one
/// contiguous allocation. Packet `i` occupies `data[i·width .. (i+1)·width]`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PacketBuf {
    width: usize,
    count: usize,
    data: Vec<u64>,
}

impl PacketBuf {
    /// An empty buffer of the given packet width.
    pub fn new(width: usize) -> Self {
        PacketBuf {
            width,
            count: 0,
            data: Vec::new(),
        }
    }

    /// An empty buffer with room for `packets` packets.
    pub fn with_capacity(width: usize, packets: usize) -> Self {
        PacketBuf {
            width,
            count: 0,
            data: Vec::with_capacity(width * packets),
        }
    }

    /// `count` all-zero packets of the given width.
    pub fn zeros(width: usize, count: usize) -> Self {
        PacketBuf {
            width,
            count,
            data: vec![0; width * count],
        }
    }

    /// A buffer holding exactly one packet (takes ownership — no copy).
    pub fn from_packet(pkt: Packet) -> Self {
        PacketBuf {
            width: pkt.len(),
            count: 1,
            data: pkt,
        }
    }

    /// Reinterpret a flat element vector as `data.len() / width` packets
    /// of `width` elements (no copy). `width = 0` requires empty data.
    pub fn from_flat(width: usize, data: Vec<u64>) -> Self {
        let count = if width == 0 {
            assert!(data.is_empty(), "width-0 buffer must be empty");
            0
        } else {
            assert_eq!(data.len() % width, 0, "flat data not a multiple of width");
            data.len() / width
        };
        PacketBuf { width, count, data }
    }

    /// Gather packets (all of width `width`) into one flat allocation.
    pub fn from_slices<'a>(width: usize, parts: impl IntoIterator<Item = &'a [u64]>) -> Self {
        let mut buf = PacketBuf::new(width);
        for p in parts {
            buf.push(p);
        }
        buf
    }

    /// Append one packet (must match the buffer width).
    pub fn push(&mut self, pkt: &[u64]) {
        debug_assert_eq!(pkt.len(), self.width, "packet width mismatch");
        self.data.extend_from_slice(pkt);
        self.count += 1;
    }

    /// Packet width `W`.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Number of packets.
    pub fn count(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Total size in field elements — the unit of `C2`.
    pub fn elems(&self) -> u64 {
        self.data.len() as u64
    }

    /// Borrow packet `i`.
    #[inline]
    pub fn pkt(&self, i: usize) -> &[u64] {
        &self.data[i * self.width..(i + 1) * self.width]
    }

    /// Mutably borrow packet `i`.
    #[inline]
    pub fn pkt_mut(&mut self, i: usize) -> &mut [u64] {
        &mut self.data[i * self.width..(i + 1) * self.width]
    }

    /// Mutably borrow two distinct packets at once (`i < j`).
    pub fn pair_mut(&mut self, i: usize, j: usize) -> (&mut [u64], &mut [u64]) {
        assert!(i < j && j < self.count);
        let w = self.width;
        let (lo, hi) = self.data.split_at_mut(j * w);
        (&mut lo[i * w..(i + 1) * w], &mut hi[..w])
    }

    /// Iterate over packet views in index order.
    pub fn iter(&self) -> impl Iterator<Item = &[u64]> + '_ {
        (0..self.count).map(move |i| self.pkt(i))
    }

    /// The whole contiguous storage.
    pub fn data(&self) -> &[u64] {
        &self.data
    }

    /// The whole contiguous storage, mutably (reductions, channels).
    pub fn data_mut(&mut self) -> &mut [u64] {
        &mut self.data
    }

    /// Split back into owned packets (copies all but conceptually final).
    pub fn into_packets(self) -> Vec<Packet> {
        (0..self.count).map(|i| self.pkt(i).to_vec()).collect()
    }

    /// Extract the single packet of a one-packet buffer (no copy).
    pub fn into_single(self) -> Packet {
        assert_eq!(self.count, 1, "expected exactly one packet");
        self.data
    }
}

/// The packed twin of [`PacketBuf`]: `count` packets of `width` field
/// elements in one **narrow-lane** allocation, the layout chosen from
/// the field's `⌈log2 q⌉` via
/// [`SymbolLayout`](crate::gf::kernels::SymbolLayout). This is the
/// columnar-arena currency of the batched serving path
/// ([`replay_batch`](crate::net::exec::replay_batch)): inputs are packed
/// once, every gemm pass streams 1–4-byte lanes instead of `u64`s, and
/// outputs unpack back to canonical `u64` only at the API boundary.
/// Pack/unpack are pure width casts — canonical elements round-trip
/// exactly, so packed serving is bit-identical to scalar serving.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedPacketBuf {
    width: usize,
    count: usize,
    /// Lane distance between consecutive packets, `≥ width`. The
    /// columnar constructors round each packet row up to a whole
    /// 32-byte SIMD tile of the layout's lanes (the arena alignment
    /// contract of `DESIGN.md §9`), so the vector gemm loops cover
    /// whole rows with no per-row ragged tail; the pad lanes are zero
    /// and stay zero (XOR/accumulate of zeros). Plain row-major
    /// buffers keep `stride == width`.
    stride: usize,
    buf: PackedBuf,
}

impl PackedPacketBuf {
    /// `count` all-zero packets of the given width in `layout` storage.
    pub fn zeros(layout: SymbolLayout, width: usize, count: usize) -> Self {
        PackedPacketBuf {
            width,
            count,
            stride: width,
            buf: PackedBuf::zeros(layout, width * count),
        }
    }

    /// Pack an unpacked [`PacketBuf`] (canonical elements) into `layout`.
    pub fn pack(layout: SymbolLayout, src: &PacketBuf) -> Self {
        PackedPacketBuf {
            width: src.width(),
            count: src.count(),
            stride: src.width(),
            buf: PackedBuf::pack(layout, src.data()),
        }
    }

    /// `width` rounded up to a whole 32-byte SIMD tile of `layout`
    /// lanes — the stride of the columnar constructors.
    fn tile_stride(layout: SymbolLayout, width: usize) -> usize {
        let lanes = 32 / layout.bytes();
        width.div_ceil(lanes) * lanes
    }

    /// Pack `B` same-shape jobs into the strided **columnar arena** of
    /// the batched replay engine: `K` packets of width `W·B`, with job
    /// `j`'s packet `k` at columns `[j·W, (j+1)·W)` and each packet row
    /// zero-padded to the tile-aligned [`stride`](Self::stride). Built
    /// append-only in storage order — no zero-fill pass over lanes that
    /// are about to be overwritten. Callers guarantee the jobs are
    /// rectangular (`K` rows each, common width `w`), as
    /// `exec::check_batch` does.
    pub fn pack_columnar(layout: SymbolLayout, jobs: &[&[Packet]], w: usize) -> Self {
        let b = jobs.len();
        let k = jobs.first().map_or(0, |job| job.len());
        let width = w * b;
        let stride = Self::tile_stride(layout, width);
        let mut buf = PackedBuf::with_capacity(layout, k * stride);
        for ki in 0..k {
            for job in jobs {
                debug_assert_eq!(job[ki].len(), w, "ragged job in columnar pack");
                buf.extend_from_u64(&job[ki]);
            }
            buf.extend_zeros(stride - width);
        }
        PackedPacketBuf {
            width,
            count: k,
            stride,
            buf,
        }
    }

    /// `count` all-zero packets of width `width` with the same
    /// tile-aligned stride as [`pack_columnar`](Self::pack_columnar) —
    /// the matching output-arena constructor, so a gemm over a columnar
    /// arena writes rows of identical shape.
    pub fn zeros_columnar(layout: SymbolLayout, width: usize, count: usize) -> Self {
        let stride = Self::tile_stride(layout, width);
        PackedPacketBuf {
            width,
            count,
            stride,
            buf: PackedBuf::zeros(layout, stride * count),
        }
    }

    /// Packet width `W`.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Number of packets.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Lane distance between consecutive packets (`≥ width`; equal for
    /// non-columnar buffers). Kernel callers use this as the gemm row
    /// length so vector loops run over whole tile-aligned rows.
    pub fn stride(&self) -> usize {
        self.stride
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Total size in field elements — the unit of `C2`. Stride padding
    /// is storage, not payload, so it never counts here.
    pub fn elems(&self) -> u64 {
        (self.width * self.count) as u64
    }

    /// Storage footprint in bytes (`elems × lane bytes`).
    pub fn bytes(&self) -> usize {
        self.buf.bytes()
    }

    pub fn layout(&self) -> SymbolLayout {
        self.buf.layout()
    }

    /// Overwrite packet `i` from canonical `u64` elements.
    pub fn set_pkt(&mut self, i: usize, pkt: &[u64]) {
        debug_assert_eq!(pkt.len(), self.width, "packet width mismatch");
        self.buf.copy_from_u64(i * self.stride, pkt);
    }

    /// Write canonical elements at a raw element offset — strided
    /// columnar arenas address sub-packet column ranges directly.
    pub fn copy_from_u64(&mut self, at: usize, src: &[u64]) {
        self.buf.copy_from_u64(at, src);
    }

    /// Packet `i`, unpacked to canonical `u64`s (pad lanes excluded).
    pub fn pkt(&self, i: usize) -> Packet {
        self.buf.unpack_range(i * self.stride, self.width)
    }

    /// `len` elements from raw element offset `at`, unpacked.
    pub fn unpack_range(&self, at: usize, len: usize) -> Vec<u64> {
        self.buf.unpack_range(at, len)
    }

    /// The underlying packed storage (kernel operand).
    pub fn buf(&self) -> &PackedBuf {
        &self.buf
    }

    /// The underlying packed storage, mutably (kernel output).
    pub fn buf_mut(&mut self) -> &mut PackedBuf {
        &mut self.buf
    }

    /// Unpack the whole buffer into a fresh [`PacketBuf`] — per packet,
    /// so stride padding never leaks into the canonical view.
    pub fn to_packet_buf(&self) -> PacketBuf {
        let mut out = PacketBuf::with_capacity(self.width, self.count);
        for i in 0..self.count {
            out.push(&self.pkt(i));
        }
        out
    }
}

/// The all-zero packet of width `w`.
pub fn pkt_zero(w: usize) -> Packet {
    vec![0; w]
}

/// `dst += src` (element-wise field addition).
pub fn pkt_add<F: Field>(f: &F, dst: &mut [u64], src: &[u64]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = f.add(*d, s);
    }
}

/// `dst += c · src` — the axpy at the heart of every coding scheme
/// (fused-reduction kernel per field, see [`Field::axpy_into`]).
pub fn pkt_add_scaled<F: Field>(f: &F, dst: &mut [u64], c: u64, src: &[u64]) {
    f.axpy_into(dst, c, src);
}

/// `c · src` as a fresh packet.
pub fn pkt_scale<F: Field>(f: &F, c: u64, src: &[u64]) -> Packet {
    let mut out = vec![0; src.len()];
    f.scale_slice(&mut out, c, src);
    out
}

/// `Σ coeffs[i] · srcs[i]` — a full linear combination (delayed-reduction
/// fast path via [`Field::lincomb_into`]).
pub fn lincomb<F: Field>(f: &F, terms: &[(u64, &[u64])], w: usize) -> Packet {
    let mut out = pkt_zero(w);
    f.lincomb_into(&mut out, terms);
    out
}

// ---------------------------------------------------------------------------
// Wire frames — the sans-IO codec of the serving front end.
//
// One frame = a fixed 40-byte little-endian header + payload. Request and
// Response payloads carry `rows × width` field elements packed at the
// field's symbol lane (the same `u8`/`u16`/`u32`/`u64` narrow-lane storage
// the kernels stream — see [`SymbolLayout`]), so a GF(2^8) request ships
// one byte per element, not eight. Error payloads carry a UTF-8 message.
// The codec owns bytes only; sockets live in `coordinator::server`.
// ---------------------------------------------------------------------------

/// Fixed size of every frame header on the wire.
pub const FRAME_HEADER_LEN: usize = 40;

/// `b"DCE1"` — the frame magic (Decentralized Coding Engine, wire v1).
pub const FRAME_MAGIC: [u8; 4] = *b"DCE1";

/// Hard caps a well-formed peer never hits; parsing rejects beyond them
/// so a corrupt or hostile header can't provoke a huge allocation.
const MAX_FRAME_DIM: u32 = 1 << 24;
const MAX_FRAME_PAYLOAD: u32 = 1 << 30;

/// What a frame carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Client → server: `K` payload rows to encode.
    Request = 2,
    /// Server → client: the `R` parity rows.
    Response = 3,
    /// Server → client: a per-request failure (UTF-8 message payload);
    /// the connection survives.
    Error = 4,
}

impl FrameKind {
    fn from_u8(v: u8) -> anyhow::Result<FrameKind> {
        match v {
            2 => Ok(FrameKind::Request),
            3 => Ok(FrameKind::Response),
            4 => Ok(FrameKind::Error),
            other => anyhow::bail!("unknown frame kind {other}"),
        }
    }
}

fn layout_from_lane(bytes: u8) -> anyhow::Result<SymbolLayout> {
    Ok(match bytes {
        1 => SymbolLayout::U8,
        2 => SymbolLayout::U16,
        4 => SymbolLayout::U32,
        8 => SymbolLayout::U64,
        other => anyhow::bail!("invalid symbol lane width {other} bytes"),
    })
}

/// The decoded fixed-size prefix of one wire frame.
///
/// Layout (little-endian): magic `"DCE1"` (4) · kind (1) · lane bytes
/// (1) · reserved (2) · tenant (8) · req_id (8) · rows (4) · width (4)
/// · payload_len (4) · pad (4) = 40 bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub kind: FrameKind,
    /// Symbol lane of the packed payload (meaningful for
    /// Request/Response; Error frames use `U8`).
    pub layout: SymbolLayout,
    /// Admission-control principal of the request.
    pub tenant: u64,
    /// Correlation id: responses echo their request's id, so one
    /// connection can pipeline without ordering guarantees.
    pub req_id: u64,
    /// Payload rows (K for requests, R for responses, 0 for errors).
    pub rows: u32,
    /// Field elements per row (0 for errors).
    pub width: u32,
    /// Payload bytes following the header.
    pub payload_len: u32,
}

impl FrameHeader {
    /// Append the 40-byte wire form to `out`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&FRAME_MAGIC);
        out.push(self.kind as u8);
        out.push(self.layout.bytes() as u8);
        out.extend_from_slice(&[0u8; 2]); // reserved
        out.extend_from_slice(&self.tenant.to_le_bytes());
        out.extend_from_slice(&self.req_id.to_le_bytes());
        out.extend_from_slice(&self.rows.to_le_bytes());
        out.extend_from_slice(&self.width.to_le_bytes());
        out.extend_from_slice(&self.payload_len.to_le_bytes());
        out.extend_from_slice(&[0u8; 4]); // pad to 40
    }

    /// Parse and validate one header. Rejects bad magic, unknown kinds,
    /// invalid lanes, oversized dimensions, and any Request/Response
    /// whose `payload_len` disagrees with `rows · width · lane`.
    pub fn parse(buf: &[u8; FRAME_HEADER_LEN]) -> anyhow::Result<FrameHeader> {
        anyhow::ensure!(buf[0..4] == FRAME_MAGIC, "bad frame magic");
        let kind = FrameKind::from_u8(buf[4])?;
        let layout = layout_from_lane(buf[5])?;
        let le8 = |o: usize| u64::from_le_bytes(buf[o..o + 8].try_into().expect("8 bytes"));
        let le4 = |o: usize| u32::from_le_bytes(buf[o..o + 4].try_into().expect("4 bytes"));
        let h = FrameHeader {
            kind,
            layout,
            tenant: le8(8),
            req_id: le8(16),
            rows: le4(24),
            width: le4(28),
            payload_len: le4(32),
        };
        anyhow::ensure!(h.rows <= MAX_FRAME_DIM, "frame rows {} too large", h.rows);
        anyhow::ensure!(h.width <= MAX_FRAME_DIM, "frame width {} too large", h.width);
        anyhow::ensure!(
            h.payload_len <= MAX_FRAME_PAYLOAD,
            "frame payload {} too large",
            h.payload_len
        );
        match h.kind {
            FrameKind::Request | FrameKind::Response => {
                let expect = (h.rows as u64)
                    .checked_mul(h.width as u64)
                    .and_then(|e| e.checked_mul(h.layout.bytes() as u64))
                    .filter(|&e| e <= MAX_FRAME_PAYLOAD as u64);
                anyhow::ensure!(
                    expect == Some(h.payload_len as u64),
                    "frame payload length {} does not match {}×{} rows at {} bytes/symbol",
                    h.payload_len,
                    h.rows,
                    h.width,
                    h.layout.bytes()
                );
            }
            FrameKind::Error => {}
        }
        Ok(h)
    }
}

/// Encode `rows` of canonical field elements as one Request/Response
/// frame, packing each element into the layout's lane (LE). Errors if a
/// value overflows the lane, rows are ragged, or dimensions exceed the
/// frame caps.
pub fn encode_rows_frame(
    out: &mut Vec<u8>,
    kind: FrameKind,
    layout: SymbolLayout,
    tenant: u64,
    req_id: u64,
    rows: &[Vec<u64>],
) -> anyhow::Result<()> {
    anyhow::ensure!(kind != FrameKind::Error, "error frames carry a message");
    let width = rows.first().map_or(0, |r| r.len());
    anyhow::ensure!(
        rows.iter().all(|r| r.len() == width),
        "ragged frame rows"
    );
    anyhow::ensure!(
        rows.len() as u64 <= MAX_FRAME_DIM as u64 && width as u64 <= MAX_FRAME_DIM as u64,
        "frame dimensions too large"
    );
    let lane = layout.bytes();
    let payload_len = rows.len() * width * lane;
    anyhow::ensure!(
        payload_len as u64 <= MAX_FRAME_PAYLOAD as u64,
        "frame payload too large"
    );
    let h = FrameHeader {
        kind,
        layout,
        tenant,
        req_id,
        rows: rows.len() as u32,
        width: width as u32,
        payload_len: payload_len as u32,
    };
    out.reserve(FRAME_HEADER_LEN + payload_len);
    h.encode_into(out);
    let limit = match layout {
        SymbolLayout::U64 => u64::MAX,
        _ => (1u64 << (8 * lane)) - 1,
    };
    for row in rows {
        for &v in row {
            anyhow::ensure!(
                v <= limit,
                "value {v} overflows the {}-byte symbol lane",
                lane
            );
            out.extend_from_slice(&v.to_le_bytes()[..lane]);
        }
    }
    Ok(())
}

/// Encode one Error frame carrying a UTF-8 message.
pub fn encode_error_frame(out: &mut Vec<u8>, tenant: u64, req_id: u64, msg: &str) {
    let bytes = msg.as_bytes();
    let take = bytes.len().min(MAX_FRAME_PAYLOAD as usize);
    let h = FrameHeader {
        kind: FrameKind::Error,
        layout: SymbolLayout::U8,
        tenant,
        req_id,
        rows: 0,
        width: 0,
        payload_len: take as u32,
    };
    out.reserve(FRAME_HEADER_LEN + take);
    h.encode_into(out);
    out.extend_from_slice(&bytes[..take]);
}

/// Unpack a Request/Response payload back into canonical `u64` rows.
/// `payload.len()` must equal `header.payload_len` (the caller read
/// exactly that many bytes).
pub fn decode_rows_frame(header: &FrameHeader, payload: &[u8]) -> anyhow::Result<Vec<Vec<u64>>> {
    anyhow::ensure!(
        header.kind != FrameKind::Error,
        "error frames carry a message, not rows"
    );
    anyhow::ensure!(
        payload.len() == header.payload_len as usize,
        "frame payload is {} bytes, header promised {}",
        payload.len(),
        header.payload_len
    );
    let (rows, width, lane) = (
        header.rows as usize,
        header.width as usize,
        header.layout.bytes(),
    );
    let mut out = Vec::with_capacity(rows);
    let mut off = 0;
    for _ in 0..rows {
        let mut row = Vec::with_capacity(width);
        for _ in 0..width {
            let mut le = [0u8; 8];
            le[..lane].copy_from_slice(&payload[off..off + lane]);
            row.push(u64::from_le_bytes(le));
            off += lane;
        }
        out.push(row);
    }
    Ok(out)
}

/// Read an Error frame's UTF-8 message (lossy on invalid bytes).
pub fn frame_error_message(header: &FrameHeader, payload: &[u8]) -> String {
    debug_assert_eq!(header.kind, FrameKind::Error);
    String::from_utf8_lossy(payload).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;

    #[test]
    fn axpy_and_lincomb_agree() {
        let f = GfPrime::default_field();
        let a: Packet = vec![1, 2, 3];
        let b: Packet = vec![10, 20, 30];
        let mut acc = pkt_zero(3);
        pkt_add_scaled(&f, &mut acc, 5, &a);
        pkt_add_scaled(&f, &mut acc, 7, &b);
        assert_eq!(acc, lincomb(&f, &[(5, &a), (7, &b)], 3));
        assert_eq!(acc, vec![75, 150, 225]);
    }

    #[test]
    fn zero_coeff_is_noop() {
        let f = GfPrime::default_field();
        let a: Packet = vec![9, 9];
        let mut acc: Packet = vec![1, 2];
        pkt_add_scaled(&f, &mut acc, 0, &a);
        assert_eq!(acc, vec![1, 2]);
    }

    #[test]
    fn flat_buffer_views_match_layout() {
        let mut buf = PacketBuf::with_capacity(3, 2);
        buf.push(&[1, 2, 3]);
        buf.push(&[4, 5, 6]);
        assert_eq!(buf.count(), 2);
        assert_eq!(buf.width(), 3);
        assert_eq!(buf.elems(), 6);
        assert_eq!(buf.pkt(0), &[1, 2, 3]);
        assert_eq!(buf.pkt(1), &[4, 5, 6]);
        assert_eq!(buf.data(), &[1, 2, 3, 4, 5, 6]);
        let views: Vec<&[u64]> = buf.iter().collect();
        assert_eq!(views, vec![&[1u64, 2, 3][..], &[4, 5, 6][..]]);
        assert_eq!(buf.clone().into_packets(), vec![vec![1, 2, 3], vec![4, 5, 6]]);
        let (a, b) = buf.pair_mut(0, 1);
        a[0] = 9;
        b[2] = 8;
        assert_eq!(buf.pkt(0), &[9, 2, 3]);
        assert_eq!(buf.pkt(1), &[4, 5, 8]);
    }

    #[test]
    fn flat_buffer_single_roundtrip() {
        let buf = PacketBuf::from_packet(vec![7, 8]);
        assert_eq!(buf.count(), 1);
        assert_eq!(buf.into_single(), vec![7, 8]);
        let zeros = PacketBuf::zeros(2, 3);
        assert_eq!(zeros.count(), 3);
        assert_eq!(zeros.elems(), 6);
        assert!(zeros.iter().all(|p| p == [0, 0]));
    }

    #[test]
    fn packed_twin_roundtrips_and_halves_storage() {
        let mut buf = PacketBuf::with_capacity(3, 2);
        buf.push(&[1, 250, 3]);
        buf.push(&[4, 5, 255]);
        let packed = PackedPacketBuf::pack(SymbolLayout::U8, &buf);
        assert_eq!(packed.width(), 3);
        assert_eq!(packed.count(), 2);
        assert_eq!(packed.elems(), 6);
        assert_eq!(packed.bytes(), 6, "one byte per element in u8 layout");
        assert_eq!(packed.pkt(0), vec![1, 250, 3]);
        assert_eq!(packed.pkt(1), vec![4, 5, 255]);
        assert_eq!(packed.to_packet_buf(), buf);
        let mut z = PackedPacketBuf::zeros(SymbolLayout::U16, 2, 2);
        z.set_pkt(1, &[7, 65535]);
        z.copy_from_u64(0, &[9]);
        assert_eq!(z.pkt(0), vec![9, 0]);
        assert_eq!(z.pkt(1), vec![7, 65535]);
        assert_eq!(z.unpack_range(1, 2), vec![0, 7]);
    }

    #[test]
    fn columnar_arena_is_tile_strided_with_zero_padding() {
        // Two jobs of K = 2 packets, w = 3 → width 6, but u8 rows round
        // up to a whole 32-byte tile.
        let jobs_a = vec![vec![1u64, 2, 3], vec![4, 5, 6]];
        let jobs_b = vec![vec![7u64, 8, 9], vec![10, 11, 12]];
        let jobs: Vec<&[Packet]> = vec![&jobs_a, &jobs_b];
        let arena = PackedPacketBuf::pack_columnar(SymbolLayout::U8, &jobs, 3);
        assert_eq!(arena.width(), 6);
        assert_eq!(arena.count(), 2);
        assert_eq!(arena.stride(), 32);
        assert_eq!(arena.buf().len(), 64, "2 rows × 32-lane stride");
        assert_eq!(arena.elems(), 12, "padding is storage, not payload");
        // Logical packets exclude the padding; pad lanes are zero.
        assert_eq!(arena.pkt(0), vec![1, 2, 3, 7, 8, 9]);
        assert_eq!(arena.pkt(1), vec![4, 5, 6, 10, 11, 12]);
        assert_eq!(arena.unpack_range(6, 26), vec![0; 26]);
        // The canonical view is padding-free too.
        let unpacked = arena.to_packet_buf();
        assert_eq!(unpacked.pkt(0), &[1, 2, 3, 7, 8, 9]);
        assert_eq!(unpacked.elems(), 12);
        // The output-arena constructor agrees on shape, and wider lanes
        // round to fewer pad lanes (u32: 8 lanes per tile).
        let out = PackedPacketBuf::zeros_columnar(SymbolLayout::U8, 6, 5);
        assert_eq!(out.stride(), arena.stride());
        assert_eq!(out.count(), 5);
        let wide = PackedPacketBuf::zeros_columnar(SymbolLayout::U32, 9, 1);
        assert_eq!(wide.stride(), 16);
        // Degenerate: a width-0 arena has stride 0 and no storage.
        let empty = PackedPacketBuf::zeros_columnar(SymbolLayout::U8, 0, 4);
        assert_eq!(empty.stride(), 0);
        assert_eq!(empty.buf().len(), 0);
        // An exact multiple of the tile needs no padding at all.
        let exact = PackedPacketBuf::zeros_columnar(SymbolLayout::U16, 32, 2);
        assert_eq!(exact.stride(), 32);
    }

    #[test]
    fn from_flat_reinterprets_without_copying_semantics() {
        let buf = PacketBuf::from_flat(2, vec![1, 2, 3, 4]);
        assert_eq!(buf.count(), 2);
        assert_eq!(buf.pkt(1), &[3, 4]);
        let empty = PacketBuf::from_flat(0, Vec::new());
        assert_eq!(empty.count(), 0);
    }

    #[test]
    fn flat_axpy_over_contiguous_storage_matches_per_packet() {
        let f = GfPrime::default_field();
        let mut buf = PacketBuf::zeros(4, 3);
        let src: Vec<u64> = (1..=12).collect();
        // One fused axpy over the whole working set...
        f.axpy_into(buf.data_mut(), 5, &src);
        // ...equals three per-packet axpys.
        let mut per = vec![pkt_zero(4); 3];
        for (i, p) in per.iter_mut().enumerate() {
            pkt_add_scaled(&f, p, 5, &src[i * 4..(i + 1) * 4]);
        }
        for i in 0..3 {
            assert_eq!(buf.pkt(i), &per[i][..]);
        }
    }

    #[test]
    fn wire_frame_roundtrips_in_every_lane() {
        for (layout, max) in [
            (SymbolLayout::U8, 255u64),
            (SymbolLayout::U16, 65_535),
            (SymbolLayout::U32, u32::MAX as u64),
            (SymbolLayout::U64, u64::MAX),
        ] {
            let rows = vec![vec![0u64, 1, max], vec![max - 1, 2, 3]];
            let mut wire = Vec::new();
            encode_rows_frame(&mut wire, FrameKind::Request, layout, 9, 42, &rows).unwrap();
            assert_eq!(wire.len(), FRAME_HEADER_LEN + 2 * 3 * layout.bytes());
            let head: [u8; FRAME_HEADER_LEN] = wire[..FRAME_HEADER_LEN].try_into().unwrap();
            let h = FrameHeader::parse(&head).unwrap();
            assert_eq!(h.kind, FrameKind::Request);
            assert_eq!(h.layout, layout);
            assert_eq!((h.tenant, h.req_id), (9, 42));
            assert_eq!((h.rows, h.width), (2, 3));
            assert_eq!(decode_rows_frame(&h, &wire[FRAME_HEADER_LEN..]).unwrap(), rows);
        }
    }

    #[test]
    fn wire_frame_rejects_corruption_and_lane_overflow() {
        let rows = vec![vec![1u64, 2]];
        // A value too wide for the lane is an encode-time error.
        assert!(encode_rows_frame(
            &mut Vec::new(),
            FrameKind::Request,
            SymbolLayout::U8,
            0,
            0,
            &[vec![256u64]],
        )
        .is_err());
        // Ragged rows are an encode-time error.
        assert!(encode_rows_frame(
            &mut Vec::new(),
            FrameKind::Response,
            SymbolLayout::U16,
            0,
            0,
            &[vec![1], vec![1, 2]],
        )
        .is_err());
        let mut wire = Vec::new();
        encode_rows_frame(&mut wire, FrameKind::Request, SymbolLayout::U16, 1, 2, &rows).unwrap();
        let head = |w: &[u8]| -> [u8; FRAME_HEADER_LEN] { w[..FRAME_HEADER_LEN].try_into().unwrap() };
        // Bad magic.
        let mut bad = wire.clone();
        bad[0] = b'X';
        assert!(FrameHeader::parse(&head(&bad)).is_err());
        // Unknown kind.
        let mut bad = wire.clone();
        bad[4] = 77;
        assert!(FrameHeader::parse(&head(&bad)).is_err());
        // Invalid lane width.
        let mut bad = wire.clone();
        bad[5] = 3;
        assert!(FrameHeader::parse(&head(&bad)).is_err());
        // Payload length disagreeing with rows × width × lane.
        let mut bad = wire.clone();
        bad[32] = bad[32].wrapping_add(1);
        assert!(FrameHeader::parse(&head(&bad)).is_err());
        // Oversized dimensions are rejected before any allocation.
        let mut bad = wire.clone();
        bad[24..28].copy_from_slice(&(MAX_FRAME_DIM + 1).to_le_bytes());
        assert!(FrameHeader::parse(&head(&bad)).is_err());
        // Short payload at decode time.
        let h = FrameHeader::parse(&head(&wire)).unwrap();
        assert!(decode_rows_frame(&h, &wire[FRAME_HEADER_LEN..wire.len() - 1]).is_err());
    }

    #[test]
    fn wire_error_frames_carry_utf8_messages() {
        let mut wire = Vec::new();
        encode_error_frame(&mut wire, 3, 7, "tenant 3 quota exhausted");
        let head: [u8; FRAME_HEADER_LEN] = wire[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = FrameHeader::parse(&head).unwrap();
        assert_eq!(h.kind, FrameKind::Error);
        assert_eq!((h.tenant, h.req_id), (3, 7));
        assert_eq!((h.rows, h.width), (0, 0));
        assert_eq!(
            frame_error_message(&h, &wire[FRAME_HEADER_LEN..]),
            "tenant 3 quota exhausted"
        );
        assert!(decode_rows_frame(&h, &wire[FRAME_HEADER_LEN..]).is_err());
    }
}

//! Packet payloads: vectors in `F_q^W`.
//!
//! Remark 2 of the paper: an A2A algorithm over `F_q` applies verbatim to
//! data vectors in `F_q^W` by viewing them as elements of the extension
//! field `F_{q^W}` while keeping the coding matrix over `F_q` — same `C1`,
//! `W×` the `C2`. We therefore represent a packet as a `W`-vector of base
//! field elements and charge `W` elements per packet on the wire.

use crate::gf::Field;

/// A packet: `W` field elements (`W = 1` for the scalar A2A of Def. 4).
pub type Packet = Vec<u64>;

/// The all-zero packet of width `w`.
pub fn pkt_zero(w: usize) -> Packet {
    vec![0; w]
}

/// `dst += src` (element-wise field addition).
pub fn pkt_add<F: Field>(f: &F, dst: &mut Packet, src: &Packet) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = f.add(*d, s);
    }
}

/// `dst += c · src` — the axpy at the heart of every coding scheme.
pub fn pkt_add_scaled<F: Field>(f: &F, dst: &mut Packet, c: u64, src: &Packet) {
    debug_assert_eq!(dst.len(), src.len());
    if c == 0 {
        return;
    }
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = f.mul_add(*d, c, s);
    }
}

/// `c · src` as a fresh packet.
pub fn pkt_scale<F: Field>(f: &F, c: u64, src: &Packet) -> Packet {
    src.iter().map(|&s| f.mul(c, s)).collect()
}

/// `Σ coeffs[i] · pkts[i]` — a full linear combination (delayed-reduction
/// fast path via [`Field::lincomb_into`]).
pub fn lincomb<F: Field>(f: &F, terms: &[(u64, &Packet)], w: usize) -> Packet {
    let mut out = pkt_zero(w);
    let slices: Vec<(u64, &[u64])> = terms.iter().map(|&(c, p)| (c, p.as_slice())).collect();
    f.lincomb_into(&mut out, &slices);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;

    #[test]
    fn axpy_and_lincomb_agree() {
        let f = GfPrime::default_field();
        let a: Packet = vec![1, 2, 3];
        let b: Packet = vec![10, 20, 30];
        let mut acc = pkt_zero(3);
        pkt_add_scaled(&f, &mut acc, 5, &a);
        pkt_add_scaled(&f, &mut acc, 7, &b);
        assert_eq!(acc, lincomb(&f, &[(5, &a), (7, &b)], 3));
        assert_eq!(acc, vec![75, 150, 225]);
    }

    #[test]
    fn zero_coeff_is_noop() {
        let f = GfPrime::default_field();
        let a: Packet = vec![9, 9];
        let mut acc: Packet = vec![1, 2];
        pkt_add_scaled(&f, &mut acc, 0, &a);
        assert_eq!(acc, vec![1, 2]);
    }
}

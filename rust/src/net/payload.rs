//! Packet payloads: vectors in `F_q^W`, stored flat.
//!
//! Remark 2 of the paper: an A2A algorithm over `F_q` applies verbatim to
//! data vectors in `F_q^W` by viewing them as elements of the extension
//! field `F_{q^W}` while keeping the coding matrix over `F_q` — same `C1`,
//! `W×` the `C2`. A logical packet is therefore a `W`-vector of base field
//! elements charged as `W` elements on the wire.
//!
//! Two representations:
//!
//! * [`Packet`] — one owned logical packet (`Vec<u64>`), the currency of
//!   collective inputs/outputs;
//! * [`PacketBuf`] — a **width-aware flat buffer**: `count` packets of
//!   `width` elements each in one contiguous allocation, with
//!   slice-indexed views. Every wire message and every per-processor
//!   working set (prepare memories, shoot accumulators) uses this form,
//!   so the axpy/lincomb kernels run over contiguous memory instead of
//!   chasing one heap allocation per packet.

use crate::gf::Field;

/// A single logical packet: `W` field elements (`W = 1` for the scalar
/// A2A of Def. 4).
pub type Packet = Vec<u64>;

/// A flat buffer of `count` packets, each `width` field elements, in one
/// contiguous allocation. Packet `i` occupies `data[i·width .. (i+1)·width]`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PacketBuf {
    width: usize,
    count: usize,
    data: Vec<u64>,
}

impl PacketBuf {
    /// An empty buffer of the given packet width.
    pub fn new(width: usize) -> Self {
        PacketBuf {
            width,
            count: 0,
            data: Vec::new(),
        }
    }

    /// An empty buffer with room for `packets` packets.
    pub fn with_capacity(width: usize, packets: usize) -> Self {
        PacketBuf {
            width,
            count: 0,
            data: Vec::with_capacity(width * packets),
        }
    }

    /// `count` all-zero packets of the given width.
    pub fn zeros(width: usize, count: usize) -> Self {
        PacketBuf {
            width,
            count,
            data: vec![0; width * count],
        }
    }

    /// A buffer holding exactly one packet (takes ownership — no copy).
    pub fn from_packet(pkt: Packet) -> Self {
        PacketBuf {
            width: pkt.len(),
            count: 1,
            data: pkt,
        }
    }

    /// Gather packets (all of width `width`) into one flat allocation.
    pub fn from_slices<'a>(width: usize, parts: impl IntoIterator<Item = &'a [u64]>) -> Self {
        let mut buf = PacketBuf::new(width);
        for p in parts {
            buf.push(p);
        }
        buf
    }

    /// Append one packet (must match the buffer width).
    pub fn push(&mut self, pkt: &[u64]) {
        debug_assert_eq!(pkt.len(), self.width, "packet width mismatch");
        self.data.extend_from_slice(pkt);
        self.count += 1;
    }

    /// Packet width `W`.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Number of packets.
    pub fn count(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Total size in field elements — the unit of `C2`.
    pub fn elems(&self) -> u64 {
        self.data.len() as u64
    }

    /// Borrow packet `i`.
    #[inline]
    pub fn pkt(&self, i: usize) -> &[u64] {
        &self.data[i * self.width..(i + 1) * self.width]
    }

    /// Mutably borrow packet `i`.
    #[inline]
    pub fn pkt_mut(&mut self, i: usize) -> &mut [u64] {
        &mut self.data[i * self.width..(i + 1) * self.width]
    }

    /// Mutably borrow two distinct packets at once (`i < j`).
    pub fn pair_mut(&mut self, i: usize, j: usize) -> (&mut [u64], &mut [u64]) {
        assert!(i < j && j < self.count);
        let w = self.width;
        let (lo, hi) = self.data.split_at_mut(j * w);
        (&mut lo[i * w..(i + 1) * w], &mut hi[..w])
    }

    /// Iterate over packet views in index order.
    pub fn iter(&self) -> impl Iterator<Item = &[u64]> + '_ {
        (0..self.count).map(move |i| self.pkt(i))
    }

    /// The whole contiguous storage.
    pub fn data(&self) -> &[u64] {
        &self.data
    }

    /// The whole contiguous storage, mutably (reductions, channels).
    pub fn data_mut(&mut self) -> &mut [u64] {
        &mut self.data
    }

    /// Split back into owned packets (copies all but conceptually final).
    pub fn into_packets(self) -> Vec<Packet> {
        (0..self.count).map(|i| self.pkt(i).to_vec()).collect()
    }

    /// Extract the single packet of a one-packet buffer (no copy).
    pub fn into_single(self) -> Packet {
        assert_eq!(self.count, 1, "expected exactly one packet");
        self.data
    }
}

/// The all-zero packet of width `w`.
pub fn pkt_zero(w: usize) -> Packet {
    vec![0; w]
}

/// `dst += src` (element-wise field addition).
pub fn pkt_add<F: Field>(f: &F, dst: &mut [u64], src: &[u64]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = f.add(*d, s);
    }
}

/// `dst += c · src` — the axpy at the heart of every coding scheme
/// (fused-reduction kernel per field, see [`Field::axpy_into`]).
pub fn pkt_add_scaled<F: Field>(f: &F, dst: &mut [u64], c: u64, src: &[u64]) {
    f.axpy_into(dst, c, src);
}

/// `c · src` as a fresh packet.
pub fn pkt_scale<F: Field>(f: &F, c: u64, src: &[u64]) -> Packet {
    let mut out = vec![0; src.len()];
    f.scale_slice(&mut out, c, src);
    out
}

/// `Σ coeffs[i] · srcs[i]` — a full linear combination (delayed-reduction
/// fast path via [`Field::lincomb_into`]).
pub fn lincomb<F: Field>(f: &F, terms: &[(u64, &[u64])], w: usize) -> Packet {
    let mut out = pkt_zero(w);
    f.lincomb_into(&mut out, terms);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;

    #[test]
    fn axpy_and_lincomb_agree() {
        let f = GfPrime::default_field();
        let a: Packet = vec![1, 2, 3];
        let b: Packet = vec![10, 20, 30];
        let mut acc = pkt_zero(3);
        pkt_add_scaled(&f, &mut acc, 5, &a);
        pkt_add_scaled(&f, &mut acc, 7, &b);
        assert_eq!(acc, lincomb(&f, &[(5, &a), (7, &b)], 3));
        assert_eq!(acc, vec![75, 150, 225]);
    }

    #[test]
    fn zero_coeff_is_noop() {
        let f = GfPrime::default_field();
        let a: Packet = vec![9, 9];
        let mut acc: Packet = vec![1, 2];
        pkt_add_scaled(&f, &mut acc, 0, &a);
        assert_eq!(acc, vec![1, 2]);
    }

    #[test]
    fn flat_buffer_views_match_layout() {
        let mut buf = PacketBuf::with_capacity(3, 2);
        buf.push(&[1, 2, 3]);
        buf.push(&[4, 5, 6]);
        assert_eq!(buf.count(), 2);
        assert_eq!(buf.width(), 3);
        assert_eq!(buf.elems(), 6);
        assert_eq!(buf.pkt(0), &[1, 2, 3]);
        assert_eq!(buf.pkt(1), &[4, 5, 6]);
        assert_eq!(buf.data(), &[1, 2, 3, 4, 5, 6]);
        let views: Vec<&[u64]> = buf.iter().collect();
        assert_eq!(views, vec![&[1u64, 2, 3][..], &[4, 5, 6][..]]);
        assert_eq!(buf.clone().into_packets(), vec![vec![1, 2, 3], vec![4, 5, 6]]);
        let (a, b) = buf.pair_mut(0, 1);
        a[0] = 9;
        b[2] = 8;
        assert_eq!(buf.pkt(0), &[9, 2, 3]);
        assert_eq!(buf.pkt(1), &[4, 5, 8]);
    }

    #[test]
    fn flat_buffer_single_roundtrip() {
        let buf = PacketBuf::from_packet(vec![7, 8]);
        assert_eq!(buf.count(), 1);
        assert_eq!(buf.into_single(), vec![7, 8]);
        let zeros = PacketBuf::zeros(2, 3);
        assert_eq!(zeros.count(), 3);
        assert_eq!(zeros.elems(), 6);
        assert!(zeros.iter().all(|p| p == [0, 0]));
    }

    #[test]
    fn flat_axpy_over_contiguous_storage_matches_per_packet() {
        let f = GfPrime::default_field();
        let mut buf = PacketBuf::zeros(4, 3);
        let src: Vec<u64> = (1..=12).collect();
        // One fused axpy over the whole working set...
        f.axpy_into(buf.data_mut(), 5, &src);
        // ...equals three per-packet axpys.
        let mut per = vec![pkt_zero(4); 3];
        for (i, p) in per.iter_mut().enumerate() {
            pkt_add_scaled(&f, p, 5, &src[i * 4..(i + 1) * 4]);
        }
        for i in 0..3 {
            assert_eq!(buf.pkt(i), &per[i][..]);
        }
    }
}

//! The synchronous round engine.
//!
//! An algorithm in the paper's sense — a *scheduling* (who talks to whom in
//! each round) plus a *coding scheme* (what linear combinations are sent) —
//! is a [`Collective`]: a state machine stepped once per round. The engine
//! [`run`]s a collective to completion while
//!
//! * enforcing the p-port constraint (≤ p sends and ≤ p receives per
//!   processor per round, no self-messages),
//! * accounting `C1` (rounds) and `C2 = Σ_t m_t` (`m_t` = largest message,
//!   in field elements, of round `t`) exactly as §I defines them,
//! * optionally recording a full message trace (used by the figure tests).
//!
//! Routing uses **preallocated per-processor inboxes** (plain `Vec`s
//! indexed by `ProcId`, grown on demand) instead of per-round hash maps,
//! and delivers each round's messages in deterministic destination-major
//! order. Because delivery order is normalised here, a collective whose
//! `step` fans out over processors with rayon (the `parallel` feature)
//! produces bit-identical runs to the sequential engine — field addition
//! is exactly associative/commutative and all parallel loops merge their
//! outputs in processor-index order.

use super::fault::{DegradedReport, FaultSpec, FaultTracker};
use super::payload::{Packet, PacketBuf};
use super::trace::TraceEvent;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Global processor identifier.
pub type ProcId = usize;

/// Per-processor result packets of a completed collective.
///
/// A `BTreeMap` (not a `HashMap`) so iteration order is deterministic:
/// callers that fold or serialize outputs get the same sequence on every
/// run, and plan compilation can hash output coefficient rows stably.
pub type Outputs = BTreeMap<ProcId, Packet>;

/// One message: a flat buffer of packets from `src` to `dst` through one
/// port.
#[derive(Clone, Debug)]
pub struct Msg {
    pub src: ProcId,
    pub dst: ProcId,
    pub payload: PacketBuf,
}

impl Msg {
    pub fn new(src: ProcId, dst: ProcId, payload: PacketBuf) -> Self {
        Msg { src, dst, payload }
    }

    /// A message carrying a single packet.
    pub fn single(src: ProcId, dst: ProcId, pkt: Packet) -> Self {
        Msg::new(src, dst, PacketBuf::from_packet(pkt))
    }

    /// Size in `F_q` elements — the unit of `C2`.
    pub fn elems(&self) -> u64 {
        self.payload.elems()
    }
}

/// A round-stepped distributed algorithm (scheduling + coding scheme).
///
/// `Send` so processor-disjoint collectives can be stepped from worker
/// threads (see [`crate::collectives::Par`]).
pub trait Collective: Send {
    /// The processors this collective touches (used for message routing by
    /// combinators; the engine itself routes by `Msg::dst`).
    fn participants(&self) -> Vec<ProcId>;

    /// True when no further rounds are needed and [`outputs`] is valid.
    ///
    /// [`outputs`]: Collective::outputs
    fn is_done(&self) -> bool;

    /// Advance one round: consume the messages delivered to this
    /// collective's processors in the previous round, emit this round's
    /// sends. An empty return with `is_done()` terminates the run.
    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg>;

    /// Per-processor result packets (valid once `is_done()`), in
    /// deterministic (`ProcId`-sorted) iteration order.
    fn outputs(&self) -> Outputs;
}

/// Engine configuration + trace storage.
#[derive(Debug)]
pub struct Sim {
    /// Ports per processor (`p` of the paper).
    pub ports: usize,
    /// Record a full message trace (figure tests, debugging).
    pub record_trace: bool,
    pub trace: Vec<TraceEvent>,
}

impl Sim {
    pub fn new(ports: usize) -> Self {
        assert!(ports >= 1, "at least one port");
        Sim {
            ports,
            record_trace: false,
            trace: Vec::new(),
        }
    }

    pub fn with_trace(ports: usize) -> Self {
        let mut s = Sim::new(ports);
        s.record_trace = true;
        s
    }
}

/// Communication-cost report of one run (the paper's metrics).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimReport {
    /// `C1` — number of rounds.
    pub c1: u64,
    /// `C2 = Σ_t m_t` — elements transferred *in sequence*.
    pub c2: u64,
    /// `m_t` per round.
    pub per_round_max: Vec<u64>,
    /// Total messages sent (all ports, all rounds).
    pub messages: u64,
    /// Total elements sent (the *bandwidth* metric the paper contrasts
    /// with; not part of `C`).
    pub bandwidth: u64,
}

impl SimReport {
    /// Evaluate the linear cost model on this run.
    pub fn cost(&self, m: &super::CostModel) -> f64 {
        m.cost(self.c1, self.c2)
    }

    /// Merge a sequentially-executed phase into this report.
    pub fn absorb(&mut self, other: &SimReport) {
        self.c1 += other.c1;
        self.c2 += other.c2;
        self.per_round_max.extend_from_slice(&other.per_round_max);
        self.messages += other.messages;
        self.bandwidth += other.bandwidth;
    }
}

/// Per-processor routing state, preallocated once per run and reused every
/// round: port counters and inboxes are `ProcId`-indexed vectors (grown on
/// demand) rather than per-round hash maps.
struct Router {
    send_used: Vec<u32>,
    recv_used: Vec<u32>,
    inboxes: Vec<Vec<Msg>>,
    /// Destinations with a non-empty inbox this round.
    touched: Vec<ProcId>,
    /// Processors with non-zero port counters this round.
    counted: Vec<ProcId>,
}

impl Router {
    fn with_capacity(n: usize) -> Self {
        Router {
            send_used: vec![0; n],
            recv_used: vec![0; n],
            inboxes: (0..n).map(|_| Vec::new()).collect(),
            touched: Vec::new(),
            counted: Vec::new(),
        }
    }

    fn ensure(&mut self, pid: ProcId) {
        if pid >= self.send_used.len() {
            self.send_used.resize(pid + 1, 0);
            self.recv_used.resize(pid + 1, 0);
            self.inboxes.resize_with(pid + 1, Vec::new);
        }
    }

    /// Validate and route one round's sends; returns `m_t`.
    fn route(
        &mut self,
        sim: &mut Sim,
        round: u64,
        out: Vec<Msg>,
        report: &mut SimReport,
    ) -> Result<u64> {
        let mut m_t = 0u64;
        for m in out {
            if m.src == m.dst {
                bail!("round {round}: self-message at processor {}", m.src);
            }
            self.ensure(m.src.max(m.dst));
            self.send_used[m.src] += 1;
            if self.send_used[m.src] == 1 {
                self.counted.push(m.src);
            }
            if self.send_used[m.src] as usize > sim.ports {
                bail!(
                    "round {round}: processor {} exceeds {} send ports",
                    m.src,
                    sim.ports
                );
            }
            self.recv_used[m.dst] += 1;
            if self.recv_used[m.dst] == 1 {
                self.counted.push(m.dst);
            }
            if self.recv_used[m.dst] as usize > sim.ports {
                bail!(
                    "round {round}: processor {} exceeds {} receive ports",
                    m.dst,
                    sim.ports
                );
            }
            let e = m.elems();
            if e == 0 {
                bail!("round {round}: empty message {} -> {}", m.src, m.dst);
            }
            m_t = m_t.max(e);
            report.messages += 1;
            report.bandwidth += e;
            if sim.record_trace {
                sim.trace.push(TraceEvent {
                    round,
                    src: m.src,
                    dst: m.dst,
                    elems: e,
                });
            }
            if self.inboxes[m.dst].is_empty() {
                self.touched.push(m.dst);
            }
            self.inboxes[m.dst].push(m);
        }
        for &p in &self.counted {
            self.send_used[p] = 0;
            self.recv_used[p] = 0;
        }
        self.counted.clear();
        Ok(m_t)
    }

    /// Drain routed messages in destination-major order (deterministic
    /// regardless of the order `step` emitted them in).
    fn drain(&mut self) -> Vec<Msg> {
        self.touched.sort_unstable();
        let mut out = Vec::new();
        for &d in &self.touched {
            out.append(&mut self.inboxes[d]);
        }
        self.touched.clear();
        out
    }
}

/// Run `coll` to completion under the p-port model; panics-free — all
/// protocol violations surface as errors naming the offending round.
pub fn run(sim: &mut Sim, coll: &mut dyn Collective) -> Result<SimReport> {
    run_loop(sim, coll, None)
}

/// The engine loop shared by [`run`] and [`run_degraded`]: one stepping
/// path, so the two execution modes cannot drift apart. When a fault
/// tracker is supplied, the messages it rejects are discarded *before*
/// routing — the schedule (and hence `C1`) is untouched, only delivery
/// and the `m_t`-based metrics see the loss.
fn run_loop(
    sim: &mut Sim,
    coll: &mut dyn Collective,
    mut tracker: Option<&mut FaultTracker<'_>>,
) -> Result<SimReport> {
    let mut report = SimReport::default();
    let cap = coll
        .participants()
        .iter()
        .copied()
        .max()
        .map_or(0, |m| m + 1);
    let mut router = Router::with_capacity(cap);
    let mut inbox: Vec<Msg> = Vec::new();
    let mut idle_guard = 0usize;
    loop {
        if coll.is_done() && inbox.is_empty() {
            break;
        }
        let mut out = coll.step(std::mem::take(&mut inbox));
        if out.is_empty() {
            if coll.is_done() {
                break;
            }
            idle_guard += 1;
            if idle_guard > 8 {
                bail!("collective stalled: {idle_guard} empty rounds without completion");
            }
            continue;
        }
        idle_guard = 0;
        let round = report.c1 + 1;
        if let Some(tr) = tracker.as_mut() {
            out.retain(|m| tr.on_message(round, m.src, m.dst, m.elems()));
        }
        let m_t = router.route(sim, round, out, &mut report)?;
        report.c1 += 1;
        report.c2 += m_t;
        report.per_round_max.push(m_t);
        inbox = router.drain();
    }
    Ok(report)
}

/// The outcome of a degraded live run: the surviving outputs and the
/// full fault analysis.
#[derive(Clone, Debug)]
pub struct DegradedRun {
    /// Outputs of processors whose state never diverged — guaranteed
    /// bit-identical to the same processors' outputs in a healthy run.
    pub outputs: Outputs,
    pub fault: DegradedReport,
}

/// Run `coll` to completion under `spec`-injected faults: the collective
/// steps exactly as in [`run`] (schedules are shape-determined — tainted
/// processors keep sending, with degraded values), but messages whose
/// sender/receiver is dead or whose link/round is erased are discarded
/// *before* routing. `C1` counts every scheduled round; `m_t`/`C2`/
/// `messages`/`bandwidth` count delivered traffic only. Outputs are
/// returned for surviving processors alone — the rest are lost and must
/// be reconstructed from the code's redundancy
/// (`codes::recovery`).
pub fn run_degraded(
    sim: &mut Sim,
    coll: &mut dyn Collective,
    spec: &FaultSpec,
) -> Result<DegradedRun> {
    let mut tracker = FaultTracker::new(spec);
    let report = run_loop(sim, coll, Some(&mut tracker))?;
    let fault = tracker.finish(report);
    let outputs: Outputs = coll
        .outputs()
        .into_iter()
        .filter(|&(pid, _)| fault.survives(pid))
        .collect();
    Ok(DegradedRun { outputs, fault })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A toy collective: processor 0 sends `x` to 1..n in ⌈(n−1)/p⌉ rounds
    /// of direct sends (deliberately naive).
    struct NaiveBroadcast {
        n: usize,
        p: usize,
        sent: usize,
        data: Packet,
        done_round: bool,
    }

    impl Collective for NaiveBroadcast {
        fn participants(&self) -> Vec<ProcId> {
            (0..self.n).collect()
        }
        fn is_done(&self) -> bool {
            self.sent >= self.n - 1
        }
        fn step(&mut self, _inbox: Vec<Msg>) -> Vec<Msg> {
            let mut out = Vec::new();
            for _ in 0..self.p {
                if self.sent >= self.n - 1 {
                    break;
                }
                self.sent += 1;
                out.push(Msg::single(0, self.sent, self.data.clone()));
            }
            self.done_round = true;
            out
        }
        fn outputs(&self) -> Outputs {
            (0..self.n).map(|i| (i, self.data.clone())).collect()
        }
    }

    #[test]
    fn counts_rounds_and_elems() {
        let mut sim = Sim::new(2);
        let mut c = NaiveBroadcast {
            n: 7,
            p: 2,
            sent: 0,
            data: vec![1, 2, 3],
            done_round: false,
        };
        let r = run(&mut sim, &mut c).unwrap();
        assert_eq!(r.c1, 3); // ⌈6/2⌉ rounds
        assert_eq!(r.c2, 9); // 3 elements per round max
        assert_eq!(r.messages, 6);
        assert_eq!(r.bandwidth, 18);
    }

    #[test]
    fn degraded_run_with_no_faults_matches_healthy() {
        let mk = || NaiveBroadcast {
            n: 7,
            p: 2,
            sent: 0,
            data: vec![1, 2, 3],
            done_round: false,
        };
        let healthy = run(&mut Sim::new(2), &mut mk()).unwrap();
        let mut c = mk();
        let deg = run_degraded(&mut Sim::new(2), &mut c, &FaultSpec::new()).unwrap();
        assert_eq!(deg.fault.delivered, healthy);
        assert_eq!(deg.fault.dropped_messages, 0);
        assert_eq!(deg.outputs.len(), 7, "everyone survives");
    }

    #[test]
    fn degraded_run_drops_crashed_senders_and_counts_rounds() {
        // Crash the only sender from round 2 on: rounds still elapse
        // (C1 = 3 as in the healthy run) but rounds 2–3 deliver nothing.
        let mut c = NaiveBroadcast {
            n: 7,
            p: 2,
            sent: 0,
            data: vec![1, 2, 3],
            done_round: false,
        };
        let spec = FaultSpec::new().crash_from(0, 2);
        let deg = run_degraded(&mut Sim::new(2), &mut c, &spec).unwrap();
        assert_eq!(deg.fault.delivered.c1, 3);
        assert_eq!(deg.fault.delivered.per_round_max, vec![3, 0, 0]);
        assert_eq!(deg.fault.delivered.messages, 2);
        assert_eq!(deg.fault.dropped_messages, 4);
        assert_eq!(deg.fault.dropped_elems, 12);
        // Receivers of dropped messages are tainted; round-1 receivers
        // and the crashed root are not *tainted* (the root is crashed).
        assert!(deg.fault.crashed.contains(&0));
        assert_eq!(deg.fault.tainted.len(), 4);
        assert!(!deg.outputs.contains_key(&0));
        assert_eq!(
            deg.outputs.keys().copied().collect::<Vec<_>>(),
            vec![1, 2],
            "only the round-1 receivers survive"
        );
    }

    #[test]
    fn port_violation_is_caught() {
        struct Flood;
        impl Collective for Flood {
            fn participants(&self) -> Vec<ProcId> {
                vec![0, 1, 2]
            }
            fn is_done(&self) -> bool {
                false
            }
            fn step(&mut self, _: Vec<Msg>) -> Vec<Msg> {
                vec![Msg::single(0, 1, vec![1]), Msg::single(0, 2, vec![1])]
            }
            fn outputs(&self) -> Outputs {
                Outputs::new()
            }
        }
        let mut sim = Sim::new(1);
        let err = run(&mut sim, &mut Flood).unwrap_err();
        assert!(err.to_string().contains("send ports"), "{err}");
    }

    #[test]
    fn self_message_is_caught() {
        struct SelfSend;
        impl Collective for SelfSend {
            fn participants(&self) -> Vec<ProcId> {
                vec![0]
            }
            fn is_done(&self) -> bool {
                false
            }
            fn step(&mut self, _: Vec<Msg>) -> Vec<Msg> {
                vec![Msg::single(0, 0, vec![1])]
            }
            fn outputs(&self) -> Outputs {
                Outputs::new()
            }
        }
        let err = run(&mut Sim::new(1), &mut SelfSend).unwrap_err();
        assert!(err.to_string().contains("self-message"), "{err}");
    }

    #[test]
    fn stall_guard_trips() {
        struct Stall;
        impl Collective for Stall {
            fn participants(&self) -> Vec<ProcId> {
                vec![0]
            }
            fn is_done(&self) -> bool {
                false
            }
            fn step(&mut self, _: Vec<Msg>) -> Vec<Msg> {
                vec![]
            }
            fn outputs(&self) -> Outputs {
                Outputs::new()
            }
        }
        assert!(run(&mut Sim::new(1), &mut Stall).is_err());
    }

    #[test]
    fn inbox_is_destination_major() {
        // Two senders cross-send; deliveries must arrive sorted by dst
        // regardless of emission order.
        struct Cross {
            t: u32,
            seen: Vec<(ProcId, ProcId)>,
        }
        impl Collective for Cross {
            fn participants(&self) -> Vec<ProcId> {
                vec![0, 1, 2]
            }
            fn is_done(&self) -> bool {
                self.t >= 2
            }
            fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
                self.seen.extend(inbox.iter().map(|m| (m.dst, m.src)));
                self.t += 1;
                if self.t == 1 {
                    // Deliberately emitted in descending-dst order.
                    vec![
                        Msg::single(0, 2, vec![1]),
                        Msg::single(2, 1, vec![2]),
                        Msg::single(1, 0, vec![3]),
                    ]
                } else {
                    vec![]
                }
            }
            fn outputs(&self) -> Outputs {
                Outputs::new()
            }
        }
        let mut c = Cross {
            t: 0,
            seen: Vec::new(),
        };
        run(&mut Sim::new(1), &mut c).unwrap();
        assert_eq!(c.seen, vec![(0, 1), (1, 2), (2, 0)]);
    }
}

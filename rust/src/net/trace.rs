//! Message traces — who sent how much to whom, per round — and plan
//! serialization for inspection.
//!
//! The figure tests (`rust/tests/figures.rs`) assert the exact
//! communication patterns of the paper's worked examples (Figs. 2–7, 9)
//! against these traces. [`plan_json`] dumps a compiled
//! [`Plan`](crate::net::plan::Plan) — schedule, ports, slot lincombs and
//! statics — as JSON (hand-rolled; the offline build has no serde) so
//! compiled schedules can be diffed, archived, and eyeballed.

/// One message observed by the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// 1-indexed round number.
    pub round: u64,
    pub src: usize,
    pub dst: usize,
    /// Message size in field elements.
    pub elems: u64,
}

/// Group a trace by round: `out[t]` holds the events of round `t+1`.
pub fn by_round(trace: &[TraceEvent]) -> Vec<Vec<TraceEvent>> {
    let max_round = trace.iter().map(|e| e.round).max().unwrap_or(0) as usize;
    let mut out = vec![Vec::new(); max_round];
    for &e in trace {
        out[e.round as usize - 1].push(e);
    }
    out
}

/// All (src, dst) pairs of a given round, sorted.
pub fn edges_of_round(trace: &[TraceEvent], round: u64) -> Vec<(usize, usize)> {
    let mut v: Vec<(usize, usize)> = trace
        .iter()
        .filter(|e| e.round == round)
        .map(|e| (e.src, e.dst))
        .collect();
    v.sort_unstable();
    v
}

/// Serialize a compiled plan as JSON: shape + statics, the per-round
/// `SendOp` schedule, every non-input slot's lincomb, and the output map.
pub fn plan_json(plan: &crate::net::plan::Plan) -> String {
    let mut rounds = Vec::with_capacity(plan.rounds().len());
    for (t, round) in plan.rounds().iter().enumerate() {
        let sends: Vec<String> = round
            .sends
            .iter()
            .map(|s| {
                let slots: Vec<String> = s.slots.iter().map(|x| x.to_string()).collect();
                format!(
                    "{{\"src\":{},\"dst\":{},\"port\":{},\"slots\":[{}]}}",
                    s.src,
                    s.dst,
                    s.port,
                    slots.join(",")
                )
            })
            .collect();
        rounds.push(format!(
            "{{\"round\":{},\"max_packets\":{},\"sends\":[{}]}}",
            t + 1,
            round.max_packets,
            sends.join(",")
        ));
    }
    let computes: Vec<String> = (plan.n_inputs..plan.n_slots())
        .map(|slot| {
            let terms: Vec<String> = plan
                .lincomb(slot)
                .iter()
                .map(|&(c, s)| format!("[{c},{s}]"))
                .collect();
            format!("{{\"slot\":{slot},\"terms\":[{}]}}", terms.join(","))
        })
        .collect();
    let outputs: Vec<String> = plan
        .output_slots()
        .iter()
        .map(|(pid, slot)| format!("\"{pid}\":{slot}"))
        .collect();
    format!(
        concat!(
            "{{\"n_inputs\":{},\"ports\":{},\"c1\":{},\"c2_per_width\":{},",
            "\"slots\":{},\"rounds\":[{}],\"computes\":[{}],\"outputs\":{{{}}}}}"
        ),
        plan.n_inputs,
        plan.ports,
        plan.c1(),
        plan.c2(1),
        plan.n_slots(),
        rounds.join(","),
        computes.join(","),
        outputs.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouping() {
        let t = vec![
            TraceEvent {
                round: 1,
                src: 0,
                dst: 1,
                elems: 1,
            },
            TraceEvent {
                round: 2,
                src: 1,
                dst: 2,
                elems: 2,
            },
            TraceEvent {
                round: 1,
                src: 2,
                dst: 0,
                elems: 1,
            },
        ];
        let g = by_round(&t);
        assert_eq!(g.len(), 2);
        assert_eq!(g[0].len(), 2);
        assert_eq!(edges_of_round(&t, 1), vec![(0, 1), (2, 0)]);
    }

    #[test]
    fn plan_json_is_wellformed() {
        let f = crate::gf::GfPrime::default_field();
        let plan = crate::net::plan::compile(1, 4, |basis| {
            Ok(Box::new(crate::collectives::TreeReduce::new(
                f,
                (0..4).collect(),
                1,
                basis,
            )))
        })
        .unwrap();
        let j = plan_json(&plan);
        assert!(j.starts_with('{') && j.ends_with('}'), "{j}");
        assert!(j.contains("\"c1\":2"), "{j}");
        assert!(j.contains("\"rounds\":[{\"round\":1"), "{j}");
        assert!(j.contains("\"outputs\":{\"0\":"), "{j}");
    }
}

//! Message traces — who sent how much to whom, per round.
//!
//! The figure tests (`rust/tests/figures.rs`) assert the exact
//! communication patterns of the paper's worked examples (Figs. 2–7, 9)
//! against these traces.

/// One message observed by the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// 1-indexed round number.
    pub round: u64,
    pub src: usize,
    pub dst: usize,
    /// Message size in field elements.
    pub elems: u64,
}

/// Group a trace by round: `out[t]` holds the events of round `t+1`.
pub fn by_round(trace: &[TraceEvent]) -> Vec<Vec<TraceEvent>> {
    let max_round = trace.iter().map(|e| e.round).max().unwrap_or(0) as usize;
    let mut out = vec![Vec::new(); max_round];
    for &e in trace {
        out[e.round as usize - 1].push(e);
    }
    out
}

/// All (src, dst) pairs of a given round, sorted.
pub fn edges_of_round(trace: &[TraceEvent], round: u64) -> Vec<(usize, usize)> {
    let mut v: Vec<(usize, usize)> = trace
        .iter()
        .filter(|e| e.round == round)
        .map(|e| (e.src, e.dst))
        .collect();
    v.sort_unstable();
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouping() {
        let t = vec![
            TraceEvent {
                round: 1,
                src: 0,
                dst: 1,
                elems: 1,
            },
            TraceEvent {
                round: 2,
                src: 1,
                dst: 2,
                elems: 2,
            },
            TraceEvent {
                round: 1,
                src: 2,
                dst: 0,
                elems: 1,
            },
        ];
        let g = by_round(&t);
        assert_eq!(g.len(), 2);
        assert_eq!(g[0].len(), 2);
        assert_eq!(edges_of_round(&t, 1), vec![(0, 1), (2, 0)]);
    }
}

//! Plan sharding: each processor's private slice of a compiled
//! [`Plan`] — the paper's "no central processor" execution model.
//!
//! The Plan IR stores every slot as a linear combination over the `K`
//! *inputs* (a row vector in `F^K`), which is global knowledge no
//! single peer holds. A [`PlanShard`] re-expresses every emission the
//! processor makes as a combination over what that processor *locally
//! knows* at that point in the schedule: its own input plus the packets
//! it received in earlier rounds. The reconstruction is a span solve —
//! each local knowledge item has a row in `F^K`, the rows are kept in
//! an incremental echelon basis, and each emission's row is expressed
//! over that basis. Solvability is guaranteed for any plan recorded
//! from a live collective: the live processor computed the very same
//! packet from the very same local state, and every operator is linear.
//!
//! The shard is pure data (local slot indices, coefficients, wire
//! schedule); executing it against a
//! [`Transport`](crate::net::transport::Transport) is
//! [`peer`](crate::net::peer)'s job.

use crate::gf::Field;
use crate::net::plan::{Plan, SlotId};
use crate::net::sim::ProcId;
use anyhow::{ensure, Context, Result};
use std::collections::{BTreeMap, BTreeSet, HashMap};

/// A linear combination over a shard's *local* knowledge arena:
/// `Σ coeff · local[idx]`, zero coefficients omitted.
pub type LocalComb = Vec<(u64, usize)>;

/// One packet this processor must materialise in a round, as a local
/// combination. The executor appends it to the knowledge arena at the
/// next free index (assignment order is the `computes` order).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LocalCompute {
    /// The global Plan slot (for diagnostics only).
    pub slot: SlotId,
    pub comb: LocalComb,
}

/// One outgoing message: local arena indices, in wire order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSend {
    pub dst: ProcId,
    pub port: u32,
    /// Arena indices of the payload packets.
    pub locals: Vec<usize>,
}

/// One expected incoming message. Its packets land in the arena at
/// `[first_local, first_local + n_slots)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardRecv {
    pub src: ProcId,
    pub port: u32,
    pub n_slots: usize,
    pub first_local: usize,
}

/// One round of a shard: materialise `computes`, ship `sends`, collect
/// `recvs` (ascending `(src, port)`), cross the barrier. Sends are
/// ordered ascending `(dst, port)` so both ends of a FIFO pair stream
/// agree on intra-round order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardRound {
    pub computes: Vec<LocalCompute>,
    pub sends: Vec<ShardSend>,
    pub recvs: Vec<ShardRecv>,
}

/// Everything one processor needs to play its part of a Plan — and
/// nothing more. No global slot table, no other rank's schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanShard {
    pub proc: ProcId,
    /// Input slots this processor contributes, ascending; they seed the
    /// knowledge arena at local indices `0..owned.len()`.
    pub owned: Vec<SlotId>,
    /// One entry per Plan round — empty rounds are kept so every rank
    /// crosses every barrier and measured `C1` equals the Plan's.
    pub rounds: Vec<ShardRound>,
    /// Total knowledge arena size after the last round.
    pub n_local: usize,
    /// The processor's final packet, over the complete arena (`None`
    /// when the Plan assigns it no output).
    pub output: Option<LocalComb>,
}

impl PlanShard {
    /// The largest packet count of any single message this shard sends
    /// or receives (ring-buffer sizing).
    pub fn max_msg_packets(&self) -> usize {
        self.rounds
            .iter()
            .flat_map(|r| {
                r.sends
                    .iter()
                    .map(|s| s.locals.len())
                    .chain(r.recvs.iter().map(|r| r.n_slots))
            })
            .max()
            .unwrap_or(0)
    }
}

/// An incremental echelon basis over `F^K` with combination tracking:
/// every basis row remembers how it was formed from the raw knowledge
/// rows, so expressing a target also yields the local coefficients.
struct SpanBasis<'f, F: Field> {
    f: &'f F,
    k: usize,
    /// Ascending pivot column; each row is zero before its pivot and 1
    /// at it.
    rows: Vec<BasisRow>,
}

struct BasisRow {
    pivot: usize,
    row: Vec<u64>,
    /// `row = Σ combo[local] · knowledge_row[local]`.
    combo: BTreeMap<usize, u64>,
}

impl<'f, F: Field> SpanBasis<'f, F> {
    fn new(f: &'f F, k: usize) -> Self {
        SpanBasis {
            f,
            k,
            rows: Vec::new(),
        }
    }

    /// Reduce `row`/`combo` in place against the basis (one ascending
    /// pivot pass — sound because every basis row is zero before its
    /// own pivot, so earlier eliminations are never undone).
    fn reduce(&self, row: &mut [u64], combo: &mut BTreeMap<usize, u64>) {
        let f = self.f;
        for b in &self.rows {
            let c = row[b.pivot];
            if c == 0 {
                continue;
            }
            for (i, &bv) in b.row.iter().enumerate().skip(b.pivot) {
                if bv != 0 {
                    row[i] = f.sub(row[i], f.mul(c, bv));
                }
            }
            for (&j, &bc) in &b.combo {
                let cur = combo.get(&j).copied().unwrap_or(0);
                let next = f.sub(cur, f.mul(c, bc));
                if next == 0 {
                    combo.remove(&j);
                } else {
                    combo.insert(j, next);
                }
            }
        }
    }

    /// Add the raw row of knowledge item `local` to the span.
    fn add(&mut self, local: usize, raw: &[u64]) {
        debug_assert_eq!(raw.len(), self.k);
        let mut row = raw.to_vec();
        let mut combo = BTreeMap::from([(local, 1u64)]);
        self.reduce(&mut row, &mut combo);
        let Some(pivot) = row.iter().position(|&v| v != 0) else {
            return; // linearly dependent — spans nothing new
        };
        let inv = self.f.inv(row[pivot]);
        for v in row.iter_mut() {
            if *v != 0 {
                *v = self.f.mul(*v, inv);
            }
        }
        for c in combo.values_mut() {
            *c = self.f.mul(*c, inv);
        }
        let at = self.rows.partition_point(|b| b.pivot < pivot);
        self.rows.insert(at, BasisRow { pivot, row, combo });
    }

    /// Express `target` over the span: `Some(comb)` with
    /// `target = Σ comb · knowledge_row`, or `None` if out of span.
    fn express(&self, target: &[u64]) -> Option<LocalComb> {
        let mut row = target.to_vec();
        let mut combo = BTreeMap::new();
        self.reduce(&mut row, &mut combo);
        if row.iter().any(|&v| v != 0) {
            return None;
        }
        // reduce() built `row - Σ c·basis = 0`, i.e. the accumulated
        // combo entered negated; flip signs to get target itself.
        Some(
            combo
                .into_iter()
                .map(|(j, c)| (self.f.neg(c), j))
                .collect(),
        )
    }
}

/// The dense `F^K` row of a Plan slot: a unit vector for inputs, the
/// stored lincomb otherwise.
fn slot_row(plan: &Plan, slot: SlotId) -> Vec<u64> {
    let mut row = vec![0u64; plan.n_inputs];
    if slot < plan.n_inputs {
        row[slot] = 1;
    } else {
        for &(c, s) in plan.lincomb(slot) {
            row[s] = c;
        }
    }
    row
}

impl Plan {
    /// Every processor the schedule involves: input owners, message
    /// endpoints, and output holders, ascending.
    pub fn participants(&self, owners: &[ProcId]) -> Vec<ProcId> {
        let mut set: BTreeSet<ProcId> = owners.iter().copied().collect();
        set.extend(self.output_slots().keys().copied());
        for round in self.rounds() {
            for op in &round.sends {
                set.insert(op.src);
                set.insert(op.dst);
            }
        }
        set.into_iter().collect()
    }

    /// Extract `proc`'s private slice of this Plan. `owners[k]` names
    /// the processor holding input `k` at the start (the systematic
    /// layout's `source(k)`). Fails only on a plan that is not locally
    /// executable — an emission outside the sender's knowledge span,
    /// which a plan recorded from a live collective can never be.
    pub fn shard<F: Field>(&self, f: &F, proc: ProcId, owners: &[ProcId]) -> Result<PlanShard> {
        ensure!(
            owners.len() == self.n_inputs,
            "owners table has {} entries for {} inputs",
            owners.len(),
            self.n_inputs
        );
        let owned: Vec<SlotId> = (0..self.n_inputs).filter(|&k| owners[k] == proc).collect();
        let mut basis = SpanBasis::new(f, self.n_inputs);
        // Global slot → local arena index, for everything this proc holds.
        let mut local_of: HashMap<SlotId, usize> = HashMap::new();
        for (i, &k) in owned.iter().enumerate() {
            local_of.insert(k, i);
            basis.add(i, &slot_row(self, k));
        }
        let mut n_local = owned.len();
        let mut rounds = Vec::with_capacity(self.rounds().len());
        for (t, round) in self.rounds().iter().enumerate() {
            let mut sr = ShardRound::default();
            // Own emissions first: solve each payload slot over the
            // knowledge accumulated in rounds < t (this round's
            // arrivals are not usable yet — the live engine delivers
            // them one round later).
            let mut sends: Vec<&crate::net::plan::SendOp> =
                round.sends.iter().filter(|op| op.src == proc).collect();
            sends.sort_by_key(|op| (op.dst, op.port));
            for op in sends {
                let mut locals = Vec::with_capacity(op.slots.len());
                for &slot in &op.slots {
                    let idx = match local_of.get(&slot) {
                        Some(&idx) => idx,
                        None => {
                            let comb =
                                basis.express(&slot_row(self, slot)).with_context(|| {
                                    format!(
                                        "slot {slot} is outside processor {proc}'s knowledge \
                                         span in round {t} — plan is not locally executable"
                                    )
                                })?;
                            let idx = n_local;
                            n_local += 1;
                            local_of.insert(slot, idx);
                            sr.computes.push(LocalCompute { slot, comb });
                            idx
                        }
                    };
                    locals.push(idx);
                }
                sr.sends.push(ShardSend {
                    dst: op.dst,
                    port: op.port,
                    locals,
                });
            }
            // Then this round's arrivals, ascending (src, port): they
            // join the arena and the span for rounds > t.
            let mut recvs: Vec<&crate::net::plan::SendOp> =
                round.sends.iter().filter(|op| op.dst == proc).collect();
            recvs.sort_by_key(|op| (op.src, op.port));
            for op in recvs {
                let first_local = n_local;
                for &slot in &op.slots {
                    let idx = n_local;
                    n_local += 1;
                    local_of.entry(slot).or_insert(idx);
                    basis.add(idx, &slot_row(self, slot));
                }
                sr.recvs.push(ShardRecv {
                    src: op.src,
                    port: op.port,
                    n_slots: op.slots.len(),
                    first_local,
                });
            }
            rounds.push(sr);
        }
        let output = match self.output_slots().get(&proc) {
            None => None,
            Some(&slot) => Some(match local_of.get(&slot) {
                Some(&idx) => vec![(1u64, idx)],
                None => basis.express(&slot_row(self, slot)).with_context(|| {
                    format!(
                        "output slot {slot} is outside processor {proc}'s final knowledge span"
                    )
                })?,
            }),
        };
        Ok(PlanShard {
            proc,
            owned,
            rounds,
            n_local,
            output,
        })
    }

    /// Shard the whole Plan: one [`PlanShard`] per participant, in
    /// [`participants`](Plan::participants) order.
    pub fn shard_all<F: Field>(&self, f: &F, owners: &[ProcId]) -> Result<Vec<PlanShard>> {
        self.participants(owners)
            .into_iter()
            .map(|p| self.shard(f, p, owners))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::{Field as _, GfPrime};

    #[test]
    fn span_basis_solves_and_rejects() {
        let f = GfPrime::default_field();
        let mut b = SpanBasis::new(&f, 3);
        b.add(0, &[1, 0, 0]);
        b.add(1, &[1, 2, 0]);
        let comb = b.express(&[4, 2, 0]).expect("in span");
        // Verify: Σ comb · knowledge = [4, 2, 0]
        let rows = [[1u64, 0, 0], [1, 2, 0]];
        let mut acc = [0u64; 3];
        for &(c, j) in &comb {
            for i in 0..3 {
                acc[i] = f.add(acc[i], f.mul(c, rows[j][i]));
            }
        }
        assert_eq!(acc, [4, 2, 0]);
        assert!(b.express(&[0, 0, 1]).is_none(), "e2 is out of span");
        // Dependent adds change nothing.
        b.add(2, &[2, 2, 0]);
        assert!(b.express(&[0, 0, 5]).is_none());
    }

    #[test]
    fn span_basis_tracks_combos_in_gf2e() {
        let f = crate::gf::AnyField::parse("gf2e:8").unwrap();
        let mut b = SpanBasis::new(&f, 4);
        let rows: Vec<Vec<u64>> = vec![
            vec![3, 1, 0, 7],
            vec![0, 5, 2, 1],
            vec![9, 0, 0, 4],
        ];
        for (i, r) in rows.iter().enumerate() {
            b.add(i, r);
        }
        // A random-ish combination must round-trip.
        let coeffs = [17u64, 101, 250];
        let mut target = vec![0u64; 4];
        for (c, r) in coeffs.iter().zip(&rows) {
            for i in 0..4 {
                target[i] = f.add(target[i], f.mul(*c, r[i]));
            }
        }
        let comb = b.express(&target).expect("in span");
        let mut acc = vec![0u64; 4];
        for &(c, j) in &comb {
            for i in 0..4 {
                acc[i] = f.add(acc[i], f.mul(c, rows[j][i]));
            }
        }
        assert_eq!(acc, target);
    }
}

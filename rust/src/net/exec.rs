//! The zero-rederivation replay executor for compiled [`Plan`]s.
//!
//! A live [`run`](crate::net::run) re-derives the entire control flow —
//! round schedules, owner lists, offset bookkeeping, routing — on every
//! execution. Replay does none of that: the [`Plan`] already fixes the
//! schedule and every coefficient, so executing it for new payload data
//! reduces to evaluating the recorded linear combinations.
//!
//! Two entry points:
//!
//! * [`replay`] — the serving path. Materialises only the *output* slots
//!   (one lincomb over the inputs per output packet, delayed-reduction
//!   kernels, rayon-parallel over independent output ops under the
//!   `parallel` feature) and reconstructs the exact [`SimReport`] from
//!   plan statics. Bit-identical to live stepping: every stored packet
//!   value is canonical (`< q`), so equal field elements are equal bits.
//! * [`replay_full`] — the inspection path. Materialises every slot
//!   round by round (rayon-parallel over the independent ops within a
//!   round) and emits the exact wire [`TraceEvent`]s, for debugging and
//!   trace tooling.

use super::payload::{pkt_zero, Packet};
use super::plan::Plan;
use super::sim::{Outputs, SimReport};
use super::trace::TraceEvent;
use crate::gf::Field;
use anyhow::{ensure, Result};

/// The result of replaying a plan against one payload set.
#[derive(Clone, Debug)]
pub struct Replay {
    /// Final packet per processor — bit-identical to a live run's
    /// [`Collective::outputs`](crate::net::Collective::outputs).
    pub outputs: Outputs,
    /// The exact report a live run would produce, from plan statics.
    pub report: SimReport,
}

/// A full (wire-level) replay: every arena slot materialised.
#[derive(Clone, Debug)]
pub struct WireReplay {
    /// `slots[s]` = the packet value of arena slot `s`.
    pub slots: Vec<Packet>,
    pub outputs: Outputs,
    pub report: SimReport,
    /// The exact trace a live `Sim::with_trace` run would record.
    pub trace: Vec<TraceEvent>,
}

/// Map `f` over `0..n` collecting results in index order —
/// rayon-parallel when the `parallel` feature is on and enabled.
fn par_map_indexed<U, F>(n: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync + Send,
{
    #[cfg(feature = "parallel")]
    if crate::net::parallel_enabled() {
        use rayon::prelude::*;
        return (0..n).into_par_iter().map(f).collect();
    }
    (0..n).map(f).collect()
}

fn check_inputs(plan: &Plan, inputs: &[Packet]) -> Result<usize> {
    ensure!(
        inputs.len() == plan.n_inputs,
        "plan expects {} inputs, got {}",
        plan.n_inputs,
        inputs.len()
    );
    let w = inputs.first().map_or(0, |x| x.len());
    ensure!(
        inputs.iter().all(|x| x.len() == w),
        "ragged input widths"
    );
    Ok(w)
}

/// Evaluate one slot's recorded lincomb against fresh inputs.
fn materialize<F: Field>(plan: &Plan, f: &F, inputs: &[Packet], w: usize, slot: usize) -> Packet {
    if slot < plan.n_inputs {
        return inputs[slot].clone();
    }
    let terms: Vec<(u64, &[u64])> = plan
        .lincomb(slot)
        .iter()
        .map(|&(c, s)| (c, inputs[s].as_slice()))
        .collect();
    let mut acc = pkt_zero(w);
    f.lincomb_into(&mut acc, &terms);
    acc
}

/// Replay the plan's outputs for new payload data (see module docs).
pub fn replay<F: Field>(plan: &Plan, f: &F, inputs: &[Packet]) -> Result<Replay> {
    let w = check_inputs(plan, inputs)?;
    let targets: Vec<(usize, usize)> = plan
        .output_slots()
        .iter()
        .map(|(&pid, &slot)| (pid, slot))
        .collect();
    let packets = par_map_indexed(targets.len(), |i| {
        materialize(plan, f, inputs, w, targets[i].1)
    });
    let outputs: Outputs = targets
        .iter()
        .map(|&(pid, _)| pid)
        .zip(packets)
        .collect();
    Ok(Replay {
        outputs,
        report: plan.report(w),
    })
}

/// Replay every arena slot round by round, with the wire trace.
pub fn replay_full<F: Field>(plan: &Plan, f: &F, inputs: &[Packet]) -> Result<WireReplay> {
    let w = check_inputs(plan, inputs)?;
    let mut slots: Vec<Packet> = inputs.to_vec();
    slots.reserve(plan.n_slots() - plan.n_inputs);
    let mut trace = Vec::new();
    for (t, round) in plan.rounds().iter().enumerate() {
        let (lo, hi) = round.new_slots;
        // The fresh ops of one round are mutually independent.
        slots.extend(par_map_indexed(hi - lo, |i| {
            materialize(plan, f, inputs, w, lo + i)
        }));
        for s in &round.sends {
            trace.push(TraceEvent {
                round: t as u64 + 1,
                src: s.src,
                dst: s.dst,
                elems: (s.slots.len() * w) as u64,
            });
        }
    }
    // Trailing output-only slots (final local combines).
    let lo = slots.len();
    let hi = plan.n_slots();
    slots.extend(par_map_indexed(hi - lo, |i| {
        materialize(plan, f, inputs, w, lo + i)
    }));
    let outputs: Outputs = plan
        .output_slots()
        .iter()
        .map(|(&pid, &slot)| (pid, slots[slot].clone()))
        .collect();
    Ok(WireReplay {
        slots,
        outputs,
        report: plan.report(w),
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::PrepareShoot;
    use crate::gf::{GfPrime, Mat};
    use crate::net::{plan::compile, run, Collective, Sim};
    use std::sync::Arc;

    #[test]
    fn replay_matches_live_run_bit_for_bit() {
        let f = GfPrime::default_field();
        let (k, p, w) = (25usize, 2usize, 3usize);
        let c = Arc::new(Mat::random(&f, k, k, 11));
        let plan = compile(p, k, |basis| {
            Ok(Box::new(PrepareShoot::new(
                f,
                (0..k).collect(),
                p,
                c.clone(),
                basis,
            )))
        })
        .unwrap();

        let inputs: Vec<Packet> = (0..k)
            .map(|i| (0..w).map(|j| f.elem((i * w + j) as u64 * 997 + 5)).collect())
            .collect();
        let mut live = PrepareShoot::new(f, (0..k).collect(), p, c.clone(), inputs.clone());
        let mut sim = Sim::with_trace(p);
        let live_report = run(&mut sim, &mut live).unwrap();

        let rep = replay(&plan, &f, &inputs).unwrap();
        assert_eq!(rep.outputs, live.outputs());
        assert_eq!(rep.report, live_report);

        let full = replay_full(&plan, &f, &inputs).unwrap();
        assert_eq!(full.outputs, live.outputs());
        assert_eq!(full.report, live_report);
        // Wire trace identical (engine records in emission order per
        // round; the recorder preserved it).
        assert_eq!(full.trace, sim.trace);
    }

    #[test]
    fn replay_rejects_wrong_shape() {
        let f = GfPrime::default_field();
        let c = Arc::new(Mat::random(&f, 4, 4, 1));
        let plan = compile(1, 4, |basis| {
            Ok(Box::new(PrepareShoot::new(
                f,
                (0..4).collect(),
                1,
                c.clone(),
                basis,
            )))
        })
        .unwrap();
        assert!(replay(&plan, &f, &[vec![1], vec![2]]).is_err());
        assert!(replay(&plan, &f, &[vec![1], vec![2], vec![3], vec![4, 5]]).is_err());
    }
}

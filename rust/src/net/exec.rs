//! The zero-rederivation replay executor for compiled [`Plan`]s.
//!
//! A live [`run`](crate::net::run) re-derives the entire control flow —
//! round schedules, owner lists, offset bookkeeping, routing — on every
//! execution. Replay does none of that: the [`Plan`] already fixes the
//! schedule and every coefficient, so executing it for new payload data
//! reduces to evaluating the recorded linear combinations.
//!
//! Four entry points:
//!
//! * [`replay`] — the single-job serving path over the raw plan.
//!   Materialises only the *output* slots (one lincomb over the inputs
//!   per output packet, delayed-reduction kernels, rayon-parallel over
//!   independent output ops under the `parallel` feature) and
//!   reconstructs the exact [`SimReport`] from plan statics.
//!   Bit-identical to live stepping: every stored packet value is
//!   canonical (`< q`), so equal field elements are equal bits.
//! * [`replay_opt`] — the single-job serving path over an
//!   [`OptimizedPlan`]: evaluate the flattened [`OutputMatrix`] rows
//!   with the dense gemm kernel. Bit-identical to [`replay`].
//! * [`replay_batch`] — the high-throughput serving path: `B` same-width
//!   jobs **packed once** into one strided columnar arena of narrow
//!   symbol lanes (`K × (W·B)` contiguous, job `j`'s columns at
//!   `[j·W, (j+1)·W)`, one `u8`/`u16`/`u32` lane per symbol instead of
//!   a `u64` — see [`Kernels`]), evaluated in a single packed gemm pass
//!   over the optimized plan (rayon-parallel over output rows) and
//!   unpacked to canonical `u64` only at the output boundary.
//!   Bit-identical per job to [`replay`]: every kernel computes the
//!   exact field value and canonical representatives are unique.
//!   [`replay_batch_kernels`] is the same path with the kernel vtable
//!   resolved ahead of time (once per plan — what `CompiledPlan` does);
//!   [`replay_batch_scalar`] keeps the unpacked `u64` engine as the
//!   reference the packed path is measured and equivalence-tested
//!   against.
//! * [`replay_full`] — the inspection path. Materialises every slot
//!   round by round (rayon-parallel over the independent ops within a
//!   round) and emits the exact wire [`TraceEvent`]s, for debugging and
//!   trace tooling.

use super::fault::{analyze_plan, DegradedReport, FaultSpec};
use super::opt::{NttBackend, OptimizedPlan, RowKind};
use super::payload::{pkt_zero, Packet, PackedPacketBuf};
use super::plan::Plan;
use super::sim::{Outputs, ProcId, SimReport};
use super::trace::TraceEvent;
use crate::gf::kernels::Kernels;
use crate::gf::matrix::gemm_into;
#[cfg(feature = "parallel")]
use crate::gf::matrix::gemm_row_into;
use crate::gf::Field;
use anyhow::{ensure, Result};

/// The result of replaying a plan against one payload set.
#[derive(Clone, Debug)]
pub struct Replay {
    /// Final packet per processor — bit-identical to a live run's
    /// [`Collective::outputs`](crate::net::Collective::outputs).
    pub outputs: Outputs,
    /// The exact report a live run would produce, from plan statics.
    pub report: SimReport,
}

/// A full (wire-level) replay: every arena slot materialised.
#[derive(Clone, Debug)]
pub struct WireReplay {
    /// `slots[s]` = the packet value of arena slot `s`.
    pub slots: Vec<Packet>,
    pub outputs: Outputs,
    pub report: SimReport,
    /// The exact trace a live `Sim::with_trace` run would record.
    pub trace: Vec<TraceEvent>,
}

/// Map `f` over `0..n` collecting results in index order —
/// rayon-parallel when the `parallel` feature is on and enabled.
fn par_map_indexed<U, F>(n: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync + Send,
{
    #[cfg(feature = "parallel")]
    if crate::net::parallel_enabled() {
        use rayon::prelude::*;
        return (0..n).into_par_iter().map(f).collect();
    }
    (0..n).map(f).collect()
}

fn check_inputs(plan: &Plan, inputs: &[Packet]) -> Result<usize> {
    ensure!(
        inputs.len() == plan.n_inputs,
        "plan expects {} inputs, got {}",
        plan.n_inputs,
        inputs.len()
    );
    let w = inputs.first().map_or(0, |x| x.len());
    ensure!(
        inputs.iter().all(|x| x.len() == w),
        "ragged input widths"
    );
    Ok(w)
}

/// Evaluate one slot's recorded lincomb against fresh inputs.
fn materialize<F: Field>(plan: &Plan, f: &F, inputs: &[Packet], w: usize, slot: usize) -> Packet {
    if slot < plan.n_inputs {
        return inputs[slot].clone();
    }
    let terms: Vec<(u64, &[u64])> = plan
        .lincomb(slot)
        .iter()
        .map(|&(c, s)| (c, inputs[s].as_slice()))
        .collect();
    let mut acc = pkt_zero(w);
    f.lincomb_into(&mut acc, &terms);
    acc
}

/// Replay the plan's outputs for new payload data (see module docs).
pub fn replay<F: Field>(plan: &Plan, f: &F, inputs: &[Packet]) -> Result<Replay> {
    let w = check_inputs(plan, inputs)?;
    let targets: Vec<(usize, usize)> = plan
        .output_slots()
        .iter()
        .map(|(&pid, &slot)| (pid, slot))
        .collect();
    let packets = par_map_indexed(targets.len(), |i| {
        materialize(plan, f, inputs, w, targets[i].1)
    });
    let outputs: Outputs = targets
        .iter()
        .map(|&(pid, _)| pid)
        .zip(packets)
        .collect();
    Ok(Replay {
        outputs,
        report: plan.report(w),
    })
}

/// Shape-check a batch: every job has `K` rows, every row the batch's
/// single common width. Returns that width (0 for an empty batch of
/// empty-width jobs — mirroring [`replay`]'s tolerance).
fn check_batch(opt: &OptimizedPlan, jobs: &[&[Packet]]) -> Result<usize> {
    let mut width = None;
    for (j, job) in jobs.iter().enumerate() {
        ensure!(
            job.len() == opt.n_inputs,
            "job {j}: plan expects {} inputs, got {}",
            opt.n_inputs,
            job.len()
        );
        let w = job.first().map_or(0, |x| x.len());
        ensure!(
            job.iter().all(|x| x.len() == w),
            "job {j}: ragged input widths"
        );
        match width {
            None => width = Some(w),
            Some(prev) => ensure!(
                prev == w,
                "job {j}: width {w} != batch width {prev} (a batch is single-width)"
            ),
        }
    }
    Ok(width.unwrap_or(0))
}

/// Reject non-canonical payload elements (`≥ q`) before packing: a
/// narrow-lane width cast is only lossless for canonical values, and
/// the table kernels index by symbol — out-of-range input must be a
/// proper error, never a silent truncation. (The scalar u64 engines
/// inherit the `Field` kernels' own behavior instead: a loud
/// out-of-bounds panic for `GF(2^w)`, implicit reduction for primes.)
fn check_canonical(kernels: &Kernels, jobs: &[&[Packet]]) -> Result<()> {
    let q = kernels.order();
    for (j, job) in jobs.iter().enumerate() {
        for row in job.iter() {
            if let Some(&v) = row.iter().find(|&&v| v >= q) {
                anyhow::bail!(
                    "job {j}: payload element {v} is not canonical (field order {q})"
                );
            }
        }
    }
    Ok(())
}

/// Evaluate the output rows `out = M · arena` — rayon-parallel over the
/// independent rows when enabled, the blocked [`gemm_into`] kernel
/// otherwise. `out` is zeroed `n_rows × n` row-major.
fn eval_rows<F: Field>(f: &F, opt: &OptimizedPlan, arena: &[u64], n: usize, out: &mut [u64]) {
    #[cfg(feature = "parallel")]
    if crate::net::parallel_enabled() && n > 0 {
        use rayon::prelude::*;
        out.par_chunks_mut(n)
            .enumerate()
            .for_each(|(i, row)| gemm_row_into(f, opt.matrix.row(i), arena, n, row));
        return;
    }
    gemm_into(
        f,
        opt.matrix.n_rows(),
        opt.matrix.k(),
        opt.matrix.rows_flat(),
        arena,
        n,
        out,
    );
}

/// Replay one job through an optimized plan: evaluate its flattened
/// [`OutputMatrix`](super::opt::OutputMatrix) rows. Bit-identical to
/// [`replay`] on the raw plan (same nonzero terms, same order, same
/// reduction chunking), with the same report.
///
/// Single-job fast path: rows are evaluated directly over the caller's
/// packet slices (rayon-parallel over the distinct rows) — no columnar
/// arena packing or output staging, which only pay off at `B > 1`.
pub fn replay_opt<F: Field>(opt: &OptimizedPlan, f: &F, inputs: &[Packet]) -> Result<Replay> {
    let w = check_batch(opt, &[inputs])?;
    let packets = par_map_indexed(opt.matrix.n_rows(), |i| {
        let terms: Vec<(u64, &[u64])> = opt
            .matrix
            .row(i)
            .iter()
            .enumerate()
            .filter(|(_, &c)| c != 0)
            .map(|(src, &c)| (c, inputs[src].as_slice()))
            .collect();
        let mut acc = pkt_zero(w);
        f.lincomb_into(&mut acc, &terms);
        acc
    });
    let outputs: Outputs = opt
        .matrix
        .assignment()
        .iter()
        .map(|(&pid, &ri)| (pid, packets[ri].clone()))
        .collect();
    Ok(Replay {
        outputs,
        report: opt.report(w),
    })
}

/// Replay `B` same-width jobs in **one pass**: pack them into a strided
/// columnar arena of narrow symbol lanes (`K × (W·B)` contiguous —
/// input `k`'s row holds job `j`'s packet at columns `[j·W, (j+1)·W)`),
/// evaluate `OutputMatrix · arena` with the field's packed gemm kernels
/// ([`Kernels`]), and unpack one [`Replay`] per job. Two wins compound:
/// per-coefficient fixed costs amortize over `W·B` columns instead of
/// `W`, and the gemm — which streams the whole arena once per output
/// row — moves 1–4-byte lanes instead of `u64`s (see
/// `benches/kernels.rs`). Outputs are bit-identical per job to
/// [`replay`] / [`replay_opt`].
///
/// Resolves the kernel vtable from `f` per call; cached serving paths
/// hold a `CompiledPlan` and use [`replay_batch_kernels`] so resolution
/// happens once per plan.
pub fn replay_batch<F: Field>(
    opt: &OptimizedPlan,
    f: &F,
    jobs: &[&[Packet]],
) -> Result<Vec<Replay>> {
    replay_batch_kernels(opt, &Kernels::for_field(f), jobs)
}

/// [`replay_batch`] with a pre-resolved kernel vtable.
pub fn replay_batch_kernels(
    opt: &OptimizedPlan,
    kernels: &Kernels,
    jobs: &[&[Packet]],
) -> Result<Vec<Replay>> {
    let w = check_batch(opt, jobs)?;
    check_canonical(kernels, jobs)?;
    let b = jobs.len();
    let wb = w * b;
    let layout = kernels.layout();

    // Pack once: strided columnar arena, K rows of W·B narrow lanes,
    // each row zero-padded to a whole SIMD tile (the arena alignment
    // contract — vector gemm loops then cover whole rows tail-free).
    let arena = PackedPacketBuf::pack_columnar(layout, jobs, w);
    let stride = arena.stride();

    // Evaluate every distinct output row once across the whole batch.
    let n_rows = opt.matrix.n_rows();
    let mut out = PackedPacketBuf::zeros_columnar(layout, wb, n_rows);
    debug_assert_eq!(out.stride(), stride, "arena/output stride drift");
    if wb > 0 {
        let rows: Vec<&[u64]> = (0..n_rows).map(|i| opt.matrix.row(i)).collect();
        kernels.gemm_rows(
            &rows,
            arena.buf(),
            stride,
            out.buf_mut(),
            crate::net::parallel_enabled(),
        )?;
    }

    // Unpack: slice each job's columns back out per processor,
    // canonical u64 at the API boundary (pad lanes never leave).
    let report = opt.report(w);
    Ok((0..b)
        .map(|j| {
            let outputs: Outputs = opt
                .matrix
                .assignment()
                .iter()
                .map(|(&pid, &ri)| (pid, out.unpack_range(ri * stride + j * w, w)))
                .collect();
            Replay {
                outputs,
                report: report.clone(),
            }
        })
        .collect())
}

/// The unpacked `u64` reference engine of [`replay_batch`] — the exact
/// pre-packing columnar path, kept as the baseline the packed kernels
/// are equivalence-tested (`tests/kernels.rs`, `tests/plan_opt.rs`) and
/// benchmarked (`benches/kernels.rs`) against.
pub fn replay_batch_scalar<F: Field>(
    opt: &OptimizedPlan,
    f: &F,
    jobs: &[&[Packet]],
) -> Result<Vec<Replay>> {
    let w = check_batch(opt, jobs)?;
    let b = jobs.len();
    let wb = w * b;
    let k = opt.n_inputs;

    // Pack: columnar arena, K rows of W·B elements.
    let mut arena = vec![0u64; k * wb];
    for (j, job) in jobs.iter().enumerate() {
        for (ki, row) in job.iter().enumerate() {
            arena[ki * wb + j * w..ki * wb + (j + 1) * w].copy_from_slice(row);
        }
    }

    // Evaluate every distinct output row once across the whole batch.
    let n_rows = opt.matrix.n_rows();
    let mut out = vec![0u64; n_rows * wb];
    eval_rows(f, opt, &arena, wb, &mut out);

    // Unpack: slice each job's columns back out per processor.
    let report = opt.report(w);
    Ok((0..b)
        .map(|j| {
            let outputs: Outputs = opt
                .matrix
                .assignment()
                .iter()
                .map(|(&pid, &ri)| (pid, out[ri * wb + j * w..ri * wb + (j + 1) * w].to_vec()))
                .collect();
            Replay {
                outputs,
                report: report.clone(),
            }
        })
        .collect())
}

/// [`replay_batch`] through a detected [`NttBackend`]: interpolate →
/// twist → fold → evaluate over the columnar `K × (W·B)` arena instead
/// of the dense gemm — `O(K log K)` per column where the gemm pays
/// `O(R·K)`. Unit (systematic) outputs are copied straight from the
/// jobs; parity outputs come from the backend's staging buffer. Outputs
/// and report are bit-identical per job to [`replay_batch`] /
/// [`replay`]: every intermediate is an exact canonical field value, so
/// equal elements are equal bits (asserted across the differential
/// matrix in `tests/ntt_backend.rs`).
pub fn replay_batch_ntt(
    opt: &OptimizedPlan,
    backend: &NttBackend,
    jobs: &[&[Packet]],
) -> Result<Vec<Replay>> {
    let w = check_batch(opt, jobs)?;
    ensure!(
        backend.k() == opt.n_inputs && backend.n_rows() == opt.matrix.n_rows(),
        "NTT backend was detected against a different plan shape"
    );
    // Same canonical-input contract as the packed dense path.
    let q = backend.order();
    for (j, job) in jobs.iter().enumerate() {
        for row in job.iter() {
            if let Some(&v) = row.iter().find(|&&v| v >= q) {
                anyhow::bail!(
                    "job {j}: payload element {v} is not canonical (field order {q})"
                );
            }
        }
    }
    let b = jobs.len();
    let wb = w * b;
    let k = opt.n_inputs;

    // Pack: columnar u64 arena, K rows of W·B elements (the transform
    // butterflies are full-width modmuls — no narrow-lane packing).
    let mut arena = vec![0u64; k * wb];
    for (j, job) in jobs.iter().enumerate() {
        for (ki, row) in job.iter().enumerate() {
            arena[ki * wb + j * w..ki * wb + (j + 1) * w].copy_from_slice(row);
        }
    }
    let parity = if wb > 0 {
        backend.parity_rows(&arena, wb)?
    } else {
        Vec::new()
    };

    // Unpack: unit rows are the job's own packets, parity rows slice
    // the staging buffer.
    let report = opt.report(w);
    Ok((0..b)
        .map(|j| {
            let outputs: Outputs = opt
                .matrix
                .assignment()
                .iter()
                .map(|(&pid, &ri)| {
                    let pkt = match backend.row_kind(ri) {
                        RowKind::Unit(src) => jobs[j][src].clone(),
                        RowKind::Parity(r) => {
                            parity[r * wb + j * w..r * wb + (j + 1) * w].to_vec()
                        }
                    };
                    (pid, pkt)
                })
                .collect();
            Replay {
                outputs,
                report: report.clone(),
            }
        })
        .collect())
}

/// The result of a degraded replay: outputs of the surviving processors
/// and the full fault analysis (identical to what a degraded live run
/// of the same collective records).
#[derive(Clone, Debug)]
pub struct DegradedReplay {
    /// Surviving outputs only — bit-identical to the healthy replay's
    /// packets at the same processors.
    pub outputs: Outputs,
    pub fault: DegradedReport,
}

/// Replay a plan under `spec`-injected faults: walk the compiled
/// schedule through the taint closure
/// ([`analyze_plan`](crate::net::fault::analyze_plan)) and materialise
/// the output lincombs of the surviving processors only. Mirrors
/// [`run_degraded`](crate::net::run_degraded) exactly — same
/// [`DegradedReport`], same surviving outputs, zero control-flow
/// rederivation.
pub fn replay_degraded<F: Field>(
    plan: &Plan,
    f: &F,
    inputs: &[Packet],
    spec: &FaultSpec,
) -> Result<DegradedReplay> {
    let w = check_inputs(plan, inputs)?;
    let fault = analyze_plan(plan, w, spec);
    let targets: Vec<(usize, usize)> = plan
        .output_slots()
        .iter()
        .filter(|&(&pid, _)| fault.survives(pid))
        .map(|(&pid, &slot)| (pid, slot))
        .collect();
    let packets = par_map_indexed(targets.len(), |i| {
        materialize(plan, f, inputs, w, targets[i].1)
    });
    let outputs: Outputs = targets.iter().map(|&(pid, _)| pid).zip(packets).collect();
    Ok(DegradedReplay { outputs, fault })
}

/// Degraded **batched** columnar replay: one taint analysis for the
/// whole batch (the failure pattern is shape-level, not per-job), then
/// one strided-arena gemm pass over *only the surviving output rows* of
/// the optimized plan — dead rows are never evaluated, so a heavily
/// degraded batch costs proportionally less than a healthy one. Returns
/// the shared [`DegradedReport`] and each job's surviving outputs,
/// bit-identical per job to [`replay_degraded`] on the raw plan.
pub fn replay_degraded_batch<F: Field>(
    plan: &Plan,
    opt: &OptimizedPlan,
    f: &F,
    jobs: &[&[Packet]],
    spec: &FaultSpec,
) -> Result<(DegradedReport, Vec<Outputs>)> {
    replay_degraded_batch_kernels(plan, opt, &Kernels::for_field(f), jobs, spec)
}

/// [`replay_degraded_batch`] with a pre-resolved kernel vtable (the
/// `CompiledPlan` serving path — resolution once per plan).
pub fn replay_degraded_batch_kernels(
    plan: &Plan,
    opt: &OptimizedPlan,
    kernels: &Kernels,
    jobs: &[&[Packet]],
    spec: &FaultSpec,
) -> Result<(DegradedReport, Vec<Outputs>)> {
    ensure!(
        plan.n_inputs == opt.n_inputs,
        "raw and optimized plan disagree on K"
    );
    let w = check_batch(opt, jobs)?;
    check_canonical(kernels, jobs)?;
    let fault = analyze_plan(plan, w, spec);
    let b = jobs.len();
    let wb = w * b;
    let layout = kernels.layout();

    let arena = PackedPacketBuf::pack_columnar(layout, jobs, w);
    let stride = arena.stride();

    // Evaluate only the rows some surviving processor needs.
    let live_rows = opt.matrix.rows_where(|pid| fault.survives(pid));
    let mut out = PackedPacketBuf::zeros_columnar(layout, wb, live_rows.len());
    if wb > 0 && !live_rows.is_empty() {
        let rows: Vec<&[u64]> = live_rows.iter().map(|&ri| opt.matrix.row(ri)).collect();
        kernels.gemm_rows(
            &rows,
            arena.buf(),
            stride,
            out.buf_mut(),
            crate::net::parallel_enabled(),
        )?;
    }

    // Resolve each surviving processor's compact row position once
    // (live_rows is ascending), not per job of the batch.
    let survivors: Vec<(ProcId, usize)> = opt
        .matrix
        .assignment()
        .iter()
        .filter(|&(&pid, _)| fault.survives(pid))
        .map(|(&pid, &ri)| {
            let p = live_rows.binary_search(&ri).expect("surviving row present");
            (pid, p)
        })
        .collect();
    let outs: Vec<Outputs> = (0..b)
        .map(|j| {
            survivors
                .iter()
                .map(|&(pid, p)| (pid, out.unpack_range(p * stride + j * w, w)))
                .collect()
        })
        .collect();
    Ok((fault, outs))
}

/// Replay every arena slot round by round, with the wire trace.
pub fn replay_full<F: Field>(plan: &Plan, f: &F, inputs: &[Packet]) -> Result<WireReplay> {
    let w = check_inputs(plan, inputs)?;
    let mut slots: Vec<Packet> = inputs.to_vec();
    slots.reserve(plan.n_slots() - plan.n_inputs);
    let mut trace = Vec::new();
    for (t, round) in plan.rounds().iter().enumerate() {
        let (lo, hi) = round.new_slots;
        // The fresh ops of one round are mutually independent.
        slots.extend(par_map_indexed(hi - lo, |i| {
            materialize(plan, f, inputs, w, lo + i)
        }));
        for s in &round.sends {
            trace.push(TraceEvent {
                round: t as u64 + 1,
                src: s.src,
                dst: s.dst,
                elems: (s.slots.len() * w) as u64,
            });
        }
    }
    // Trailing output-only slots (final local combines).
    let lo = slots.len();
    let hi = plan.n_slots();
    slots.extend(par_map_indexed(hi - lo, |i| {
        materialize(plan, f, inputs, w, lo + i)
    }));
    let outputs: Outputs = plan
        .output_slots()
        .iter()
        .map(|(&pid, &slot)| (pid, slots[slot].clone()))
        .collect();
    Ok(WireReplay {
        slots,
        outputs,
        report: plan.report(w),
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::PrepareShoot;
    use crate::gf::{GfPrime, Mat};
    use crate::net::{plan::compile, run, Collective, Sim};
    use std::sync::Arc;

    #[test]
    fn replay_matches_live_run_bit_for_bit() {
        let f = GfPrime::default_field();
        let (k, p, w) = (25usize, 2usize, 3usize);
        let c = Arc::new(Mat::random(&f, k, k, 11));
        let plan = compile(p, k, |basis| {
            Ok(Box::new(PrepareShoot::new(
                f,
                (0..k).collect(),
                p,
                c.clone(),
                basis,
            )))
        })
        .unwrap();

        let inputs: Vec<Packet> = (0..k)
            .map(|i| (0..w).map(|j| f.elem((i * w + j) as u64 * 997 + 5)).collect())
            .collect();
        let mut live = PrepareShoot::new(f, (0..k).collect(), p, c.clone(), inputs.clone());
        let mut sim = Sim::with_trace(p);
        let live_report = run(&mut sim, &mut live).unwrap();

        let rep = replay(&plan, &f, &inputs).unwrap();
        assert_eq!(rep.outputs, live.outputs());
        assert_eq!(rep.report, live_report);

        let full = replay_full(&plan, &f, &inputs).unwrap();
        assert_eq!(full.outputs, live.outputs());
        assert_eq!(full.report, live_report);
        // Wire trace identical (engine records in emission order per
        // round; the recorder preserved it).
        assert_eq!(full.trace, sim.trace);
    }

    #[test]
    fn optimized_and_batched_replay_bit_identical_to_raw() {
        let f = GfPrime::default_field();
        let (k, p) = (16usize, 2usize);
        let c = Arc::new(Mat::random(&f, k, k, 3));
        let plan = compile(p, k, |basis| {
            Ok(Box::new(PrepareShoot::new(
                f,
                (0..k).collect(),
                p,
                c.clone(),
                basis,
            )))
        })
        .unwrap();
        let opt = crate::net::opt::optimize(&plan);
        let mut rng = crate::util::Rng::new(17);
        for w in [1usize, 5] {
            let jobs: Vec<Vec<Packet>> = (0..4)
                .map(|_| {
                    (0..k)
                        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                        .collect()
                })
                .collect();
            let singles: Vec<Replay> =
                jobs.iter().map(|x| replay(&plan, &f, x).unwrap()).collect();
            let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();
            let batched = replay_batch(&opt, &f, &refs).unwrap();
            assert_eq!(batched.len(), jobs.len());
            // The packed path is bit-identical to the u64 reference
            // engine (and resolving kernels ahead of time changes
            // nothing).
            let scalar = replay_batch_scalar(&opt, &f, &refs).unwrap();
            let pre = replay_batch_kernels(&opt, &Kernels::for_field(&f), &refs).unwrap();
            for (j, (bj, sj)) in batched.iter().zip(&scalar).enumerate() {
                assert_eq!(bj.outputs, sj.outputs, "w={w} job {j}: packed vs scalar");
                assert_eq!(pre[j].outputs, sj.outputs, "w={w} job {j}: pre-resolved");
            }
            for (j, (single, batch)) in singles.iter().zip(&batched).enumerate() {
                assert_eq!(batch.outputs, single.outputs, "w={w} job {j}: outputs");
                assert_eq!(batch.report, single.report, "w={w} job {j}: report");
                let one = replay_opt(&opt, &f, &jobs[j]).unwrap();
                assert_eq!(one.outputs, single.outputs, "w={w} job {j}: replay_opt");
                assert_eq!(one.report, single.report, "w={w} job {j}: opt report");
            }
        }
    }

    #[test]
    fn replay_batch_rejects_non_canonical_elements() {
        // Out-of-field payload values must be a proper Err from the
        // packed path — never a silent narrow-lane truncation (and
        // never the worker-killing panic the old GF(2^w) scalar path
        // produced).
        let f = crate::gf::Gf2e::new(8).unwrap();
        let c = Arc::new(Mat::random(&f, 4, 4, 9));
        let ff = f.clone();
        let plan = compile(1, 4, |basis| {
            Ok(Box::new(PrepareShoot::new(
                ff,
                (0..4).collect(),
                1,
                c.clone(),
                basis,
            )))
        })
        .unwrap();
        let opt = crate::net::opt::optimize(&plan);
        let bad: Vec<Packet> = vec![vec![1], vec![300], vec![3], vec![4]];
        let err = replay_batch(&opt, &f, &[&bad]).unwrap_err();
        assert!(err.to_string().contains("not canonical"), "{err}");
        let spec = crate::net::fault::FaultSpec::new();
        assert!(replay_degraded_batch(&plan, &opt, &f, &[&bad], &spec).is_err());
    }

    #[test]
    fn replay_batch_rejects_mixed_widths_and_wrong_k() {
        let f = GfPrime::default_field();
        let c = Arc::new(Mat::random(&f, 4, 4, 1));
        let plan = compile(1, 4, |basis| {
            Ok(Box::new(PrepareShoot::new(
                f,
                (0..4).collect(),
                1,
                c.clone(),
                basis,
            )))
        })
        .unwrap();
        let opt = crate::net::opt::optimize(&plan);
        let a: Vec<Packet> = vec![vec![1], vec![2], vec![3], vec![4]];
        let wide: Vec<Packet> = vec![vec![1, 1], vec![2, 2], vec![3, 3], vec![4, 4]];
        let short: Vec<Packet> = vec![vec![1], vec![2]];
        assert!(replay_batch(&opt, &f, &[&a, &wide]).is_err(), "mixed widths");
        assert!(replay_batch(&opt, &f, &[&a, &short]).is_err(), "wrong K");
        assert!(replay_batch(&opt, &f, &[]).unwrap().is_empty(), "B = 0 ok");
    }

    #[test]
    fn degraded_replay_matches_degraded_live_run() {
        use crate::net::fault::{FaultSpec, POST_RUN};
        use crate::net::sim::run_degraded;
        let f = GfPrime::default_field();
        let (k, p, w) = (16usize, 2usize, 3usize);
        let c = Arc::new(Mat::random(&f, k, k, 23));
        let plan = compile(p, k, |basis| {
            Ok(Box::new(PrepareShoot::new(
                f,
                (0..k).collect(),
                p,
                c.clone(),
                basis,
            )))
        })
        .unwrap();
        let opt = crate::net::opt::optimize(&plan);
        let inputs: Vec<Packet> = (0..k)
            .map(|i| (0..w).map(|j| f.elem((i * w + j) as u64 * 131 + 7)).collect())
            .collect();
        let healthy = replay(&plan, &f, &inputs).unwrap();
        for spec in [
            FaultSpec::new(),
            FaultSpec::new().crash_after(3).crash_after(11),
            FaultSpec::new().crash_from(5, 2),
            FaultSpec::new().erase(1, 1, 2).drop_link(0, 4),
            FaultSpec::random_crashes(9, &(0..k).collect::<Vec<_>>(), 4, POST_RUN),
        ] {
            let mut live = PrepareShoot::new(f, (0..k).collect(), p, c.clone(), inputs.clone());
            let live_deg = run_degraded(&mut Sim::new(p), &mut live, &spec).unwrap();
            let rep_deg = replay_degraded(&plan, &f, &inputs, &spec).unwrap();
            assert_eq!(rep_deg.fault, live_deg.fault, "{spec:?}: fault analysis");
            assert_eq!(rep_deg.outputs, live_deg.outputs, "{spec:?}: surviving outputs");
            // Survivors are bit-identical to the healthy run.
            for (pid, pkt) in &rep_deg.outputs {
                assert_eq!(pkt, &healthy.outputs[pid], "{spec:?}: survivor {pid}");
            }
            // The batched columnar path agrees per job.
            let jobs = [inputs.as_slice(), inputs.as_slice()];
            let (fault_b, outs_b) =
                replay_degraded_batch(&plan, &opt, &f, &jobs, &spec).unwrap();
            assert_eq!(fault_b, rep_deg.fault, "{spec:?}: batch fault analysis");
            for (j, outs) in outs_b.iter().enumerate() {
                assert_eq!(outs, &rep_deg.outputs, "{spec:?}: batch job {j}");
            }
        }
    }

    #[test]
    fn replay_rejects_wrong_shape() {
        let f = GfPrime::default_field();
        let c = Arc::new(Mat::random(&f, 4, 4, 1));
        let plan = compile(1, 4, |basis| {
            Ok(Box::new(PrepareShoot::new(
                f,
                (0..4).collect(),
                1,
                c.clone(),
                basis,
            )))
        })
        .unwrap();
        assert!(replay(&plan, &f, &[vec![1], vec![2]]).is_err());
        assert!(replay(&plan, &f, &[vec![1], vec![2], vec![3], vec![4, 5]]).is_err());
    }
}

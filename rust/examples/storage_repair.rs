//! Distributed-storage node repair (§I: *"Regenerating codes […] are a
//! special use case of our framework"*).
//!
//! A `[20, 16]` systematic RS-coded store loses a node. Repair is itself
//! a decentralized encoding problem with `R = 1`: any `K` survivors hold
//! the data, the replacement node needs one specific linear combination
//! of what they hold — i.e. a *scaled all-to-one reduce* (Definition 3),
//! whose coefficients come from inverting the survivor subsystem.
//!
//! The demo encodes, fails nodes (systematic and parity), and repairs
//! each through the round engine, reporting the repair's C1/C2 against
//! the naive "ship K packets to the newcomer" baseline.
//!
//! ```bash
//! cargo run --release --example storage_repair
//! ```

use dce::prelude::*;

fn main() -> anyhow::Result<()> {
    let f = GfPrime::default_field();
    let (k, r, w, ports) = (16usize, 4usize, 128usize, 1usize);
    let code = GrsCode::structured(&f, k, r, 2)?;

    // The store: node i holds codeword coordinate i (W-wide payloads).
    let mut rng = Rng::new(77);
    let data: Vec<Packet> = (0..k)
        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
        .collect();
    let parity = code.parity_matrix(&f);
    let mut store: Vec<Packet> = data.clone();
    for j in 0..r {
        let mut p = vec![0u64; w];
        let terms: Vec<(u64, &[u64])> =
            (0..k).map(|i| (parity[(i, j)], data[i].as_slice())).collect();
        f.lincomb_into(&mut p, &terms);
        store.push(p);
    }

    println!("== repairing failed nodes of a [{}, {k}] RS store, W={w} ==", k + r);
    let gsys = Mat::identity(&f, k).hstack(&parity);
    for failed in [3usize, k + 2, 0, k + r - 1] {
        // Pick K helper nodes (any K survivors).
        let mut helpers: Vec<usize> = (0..k + r).filter(|&i| i != failed).collect();
        rng.shuffle(&mut helpers);
        helpers.truncate(k);
        helpers.sort_unstable();
        // Coefficients: solve  cw_failed = Σ_h c_h · cw_h.
        // Columns of G_sys: cw_i = x·g_i  ⇒  need c with G_H·c = g_failed.
        let gh = Mat::from_fn(k, k, |row, h| gsys[(row, helpers[h])]);
        let gf_col = code_col(&gsys, failed);
        let ghinv = gh
            .inverse(&f)
            .expect("any K columns of an MDS generator are independent");
        // c = G_H^{-1}·g_failed (column convention).
        let c: Vec<u64> = (0..k)
            .map(|row| {
                let mut acc = 0u64;
                for t in 0..k {
                    acc = f.add(acc, f.mul(ghinv[(row, t)], gf_col[t]));
                }
                acc
            })
            .collect();

        // Decentralized repair: helpers pre-scale and reduce to the
        // newcomer (a fresh processor id).
        let newcomer: ProcId = k + r;
        let mut procs = vec![newcomer];
        procs.extend(helpers.iter().copied());
        let mut inputs: Vec<Packet> = vec![vec![0; w]];
        for (h, &node) in helpers.iter().enumerate() {
            inputs.push(pkt_scale(&f, c[h], &store[node]));
        }
        let mut reduce = TreeReduce::new(f, procs, ports, inputs);
        let rep = run(&mut Sim::new(ports), &mut reduce)?;
        let rebuilt = reduce_output(&reduce, newcomer);
        anyhow::ensure!(rebuilt == store[failed], "repair of node {failed} failed");
        println!(
            "node {failed:>2} repaired: C1 = {} rounds, C2 = {:>5} elems (naive: C1 = {}, C2 = {})",
            rep.c1,
            rep.c2,
            k.div_ceil(ports),
            k * w / ports,
        );
    }
    println!("all repairs verified against the original store");
    Ok(())
}

fn code_col(g: &Mat, j: usize) -> Vec<u64> {
    (0..g.rows).map(|i| g[(i, j)]).collect()
}

fn reduce_output<F: Field>(red: &TreeReduce<F>, root: ProcId) -> Packet {
    red.outputs()[&root].clone()
}

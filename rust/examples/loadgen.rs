//! Load generator for the high-concurrency serving tier.
//!
//! Drives the event-driven dispatcher ([`EncodeService`]) — or its
//! TCP-framed front end ([`WireServer`]) — with many concurrent
//! clients over mixed request widths, and reports client-observed
//! latency percentiles (p50/p99/p999) plus aggregate throughput.
//!
//! Two load models:
//!
//! * **closed** (default): each client keeps exactly one request in
//!   flight — submit, wait, repeat. Measures the service's best-case
//!   round-trip latency under N-way concurrency.
//! * **open**: each client fires at a fixed tick so the *offered* rate
//!   is `--rate` requests/s across all clients, using the non-blocking
//!   admission path; typed [`ServeRejection::Overloaded`] refusals are
//!   counted (load shedding), not retried. Measures behavior at and
//!   past saturation.
//!
//! One response per client is cross-checked bit-for-bit against the
//! direct single-job replay path, so a run doubles as an end-to-end
//! correctness probe.
//!
//! ```bash
//! cargo run --release --example loadgen                        # 64 closed-loop clients
//! cargo run --release --example loadgen -- --mode open --rate 2000
//! cargo run --release --example loadgen -- --wire              # framed TCP front end
//! cargo run --release --example loadgen -- --peer shmem        # peer-engine collectives
//! cargo run --release --example loadgen -- --faults 2          # degraded (repair) path
//! cargo run --release --example loadgen -- --json loadgen.json
//! ```

use anyhow::{bail, Context, Result};
use dce::prelude::*;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Request widths cycled per client/request — mixed on purpose, so the
/// dispatcher's per-width queues and the plan cache both see a spread.
const WIDTHS: [usize; 6] = [2, 3, 4, 6, 8, 16];

struct Opts {
    clients: usize,
    requests: usize,
    open_loop: bool,
    rate: f64,
    wire: bool,
    peer: Option<TransportKind>,
    faults: usize,
    workers: usize,
    json: Option<String>,
}

impl Opts {
    fn parse() -> Result<Opts> {
        let mut o = Opts {
            clients: 64,
            requests: 50,
            open_loop: false,
            rate: 2000.0,
            wire: false,
            peer: None,
            faults: 0,
            workers: 4,
            json: None,
        };
        let mut args = std::env::args().skip(1);
        while let Some(flag) = args.next() {
            let mut val = |name: &str| -> Result<String> {
                args.next().with_context(|| format!("{name} needs a value"))
            };
            match flag.as_str() {
                "--clients" => o.clients = val("--clients")?.parse()?,
                "--requests" => o.requests = val("--requests")?.parse()?,
                "--mode" => {
                    o.open_loop = match val("--mode")?.as_str() {
                        "closed" => false,
                        "open" => true,
                        other => bail!("--mode must be closed|open, got {other:?}"),
                    }
                }
                "--rate" => o.rate = val("--rate")?.parse()?,
                "--wire" => o.wire = true,
                "--peer" => o.peer = Some(val("--peer")?.parse()?),
                "--faults" => o.faults = val("--faults")?.parse()?,
                "--workers" => o.workers = val("--workers")?.parse()?,
                "--json" => o.json = Some(val("--json")?),
                "--help" | "-h" => {
                    println!(
                        "loadgen: --clients N --requests N --mode closed|open --rate RPS \
                         --wire --peer channel|shmem|tcp --faults N --workers N --json PATH"
                    );
                    std::process::exit(0);
                }
                other => bail!("unknown flag {other:?} (try --help)"),
            }
        }
        anyhow::ensure!(o.clients >= 1 && o.requests >= 1 && o.workers >= 1);
        anyhow::ensure!(o.rate > 0.0, "--rate must be positive");
        Ok(o)
    }
}

/// What one client brings back from its run.
#[derive(Default)]
struct ClientResult {
    /// Client-observed submit→response latencies, µs.
    lats: Vec<u64>,
    /// Typed `Overloaded` refusals (open loop only).
    rejects: u64,
    /// Responses that came back `Err`.
    failures: u64,
    /// Did the spot-checked response match the direct encode path?
    match_direct: bool,
}

fn pct(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Per-client request pool, generated outside the timed region.
fn build_pool(cfg: &JobConfig, client: usize, requests: usize) -> Vec<Vec<Vec<u64>>> {
    let f = cfg.any_field().expect("field parses");
    let mut rng = Rng::new(cfg.seed ^ (client as u64 + 1).wrapping_mul(0x9E37_79B9));
    (0..requests)
        .map(|i| {
            let w = WIDTHS[(client + i) % WIDTHS.len()];
            (0..cfg.k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect()
        })
        .collect()
}

/// Bit-for-bit spot check of one (payload, response) pair against the
/// direct single-job replay path.
fn matches_direct(oracle: &(EncodeJob, PlanCache), x: &[Vec<u64>], y: &[Vec<u64>]) -> bool {
    match oracle
        .0
        .encode(&oracle.1, &[x], &ExecOptions::cached(&oracle.1))
    {
        Ok(out) => out.coded[0] == y,
        Err(_) => false,
    }
}

/// Closed loop: one request in flight, submit→recv round trips.
fn run_closed(
    svc: &EncodeService,
    tenant: u64,
    pool: &[Vec<Vec<u64>>],
    oracle: &(EncodeJob, PlanCache),
) -> Result<ClientResult> {
    let mut out = ClientResult {
        match_direct: true,
        ..ClientResult::default()
    };
    for (i, x) in pool.iter().enumerate() {
        let t0 = Instant::now();
        let rx = svc.submit_tenant(tenant, x.clone())?;
        let resp = rx.recv().context("service dropped a reply")?;
        out.lats.push(t0.elapsed().as_micros() as u64);
        match resp.y {
            Ok(y) => {
                if i == 0 && !matches_direct(oracle, x, &y) {
                    out.match_direct = false;
                }
            }
            Err(_) => out.failures += 1,
        }
    }
    Ok(out)
}

/// Open loop: fire at a fixed per-client tick via the non-blocking
/// admission path; a drainer thread collects responses so a slow
/// service never stalls the offered load.
fn run_open(
    svc: &EncodeService,
    tenant: u64,
    pool: &[Vec<Vec<u64>>],
    interval: Duration,
    oracle: &(EncodeJob, PlanCache),
) -> Result<ClientResult> {
    type Pending = (Instant, usize, mpsc::Receiver<EncodeResponse>);
    let (tx, rx) = mpsc::channel::<Pending>();
    let drainer = std::thread::spawn(move || {
        let mut lats = Vec::new();
        let mut failures = 0u64;
        let mut first_ok: Option<(usize, Vec<Vec<u64>>)> = None;
        for (t0, idx, reply) in rx {
            match reply.recv() {
                Ok(resp) => {
                    lats.push(t0.elapsed().as_micros() as u64);
                    match resp.y {
                        Ok(y) => {
                            if first_ok.is_none() {
                                first_ok = Some((idx, y));
                            }
                        }
                        Err(_) => failures += 1,
                    }
                }
                Err(_) => failures += 1,
            }
        }
        (lats, failures, first_ok)
    });

    let mut rejects = 0u64;
    let mut next = Instant::now();
    'send: for (i, x) in pool.iter().enumerate() {
        let now = Instant::now();
        if now < next {
            std::thread::sleep(next - now);
        }
        next += interval;
        let t0 = Instant::now();
        match svc.try_submit_tenant(tenant, x.clone()) {
            Ok(reply) => tx.send((t0, i, reply)).expect("drainer alive"),
            Err(e) => match e.downcast_ref::<ServeRejection>() {
                Some(ServeRejection::Overloaded { .. }) => rejects += 1,
                Some(ServeRejection::ServiceStopped) => break 'send,
                None => return Err(e),
            },
        }
    }
    drop(tx);
    let (lats, failures, first_ok) = drainer.join().expect("drainer panicked");
    let match_direct = match first_ok {
        Some((idx, y)) => matches_direct(oracle, &pool[idx], &y),
        // Every request shed: nothing to check, nothing wrong.
        None => true,
    };
    Ok(ClientResult {
        lats,
        rejects,
        failures,
        match_direct,
    })
}

/// Closed loop through the peer engine: every request executes the
/// full peer-to-peer collective (thread ranks over a real transport) —
/// loadgen's stress mode for `net::peer` + the transports.
fn run_peer_loop(
    job: &EncodeJob,
    cache: &PlanCache,
    kind: TransportKind,
    pool: &[Vec<Vec<u64>>],
    oracle: &(EncodeJob, PlanCache),
) -> Result<ClientResult> {
    let opts = ExecOptions::cached(cache).engine(Engine::Peer(kind));
    let mut out = ClientResult {
        match_direct: true,
        ..ClientResult::default()
    };
    for (i, x) in pool.iter().enumerate() {
        let t0 = Instant::now();
        match job.encode(cache, &[x.as_slice()], &opts) {
            Ok(res) => {
                out.lats.push(t0.elapsed().as_micros() as u64);
                if i == 0 && !matches_direct(oracle, x, &res.coded[0]) {
                    out.match_direct = false;
                }
            }
            Err(_) => out.failures += 1,
        }
    }
    Ok(out)
}

/// Closed loop over the framed TCP front end: one connection per
/// client, strict send→recv pipelining of depth 1.
fn run_wire(
    addr: std::net::SocketAddr,
    layout: SymbolLayout,
    tenant: u64,
    pool: &[Vec<Vec<u64>>],
    oracle: &(EncodeJob, PlanCache),
) -> Result<ClientResult> {
    let mut cli = WireClient::connect(addr, layout)?;
    let mut out = ClientResult {
        match_direct: true,
        ..ClientResult::default()
    };
    for (i, x) in pool.iter().enumerate() {
        let t0 = Instant::now();
        cli.send(tenant, i as u64, x)?;
        let (req_id, y) = cli.recv()?;
        out.lats.push(t0.elapsed().as_micros() as u64);
        anyhow::ensure!(req_id == i as u64, "response out of order at depth 1");
        match y {
            Ok(y) => {
                if i == 0 && !matches_direct(oracle, x, &y) {
                    out.match_direct = false;
                }
            }
            Err(_) => out.failures += 1,
        }
    }
    Ok(out)
}

fn main() -> Result<()> {
    let opts = Opts::parse()?;
    if opts.wire && opts.faults > 0 {
        bail!("--wire serves the healthy replay path; --faults needs the threaded mode");
    }
    if opts.wire && opts.open_loop {
        bail!("--wire is closed-loop (depth-1 pipelining per connection); drop --mode open");
    }
    if opts.peer.is_some() && (opts.wire || opts.open_loop || opts.faults > 0) {
        bail!("--peer is a closed-loop healthy mode; drop --wire/--mode open/--faults");
    }

    let mut cfg = JobConfig {
        k: 32,
        r: 8,
        ..JobConfig::default()
    };
    cfg.serve.max_batch = 16;
    cfg.serve.max_delay_us = 200;
    cfg.serve.queue_depth = (opts.clients * 4).max(64);
    cfg.serve.tenant_quota = cfg.serve.queue_depth;
    anyhow::ensure!(
        opts.faults <= cfg.r,
        "--faults {} exceeds R = {} (unrecoverable)",
        opts.faults,
        cfg.r
    );

    let oracle = (EncodeJob::synthetic(cfg.clone())?, PlanCache::new());
    let pools: Vec<_> = (0..opts.clients)
        .map(|c| build_pool(&cfg, c, opts.requests))
        .collect();

    let mode = if opts.open_loop { "open" } else { "closed" };
    let front = if opts.wire {
        "wire".to_string()
    } else if let Some(kind) = opts.peer {
        format!("peer-{kind}")
    } else {
        "threaded".to_string()
    };
    println!(
        "== loadgen: {} clients x {} requests, {mode} loop, {front} front end, \
         {} workers, K={} R={} widths {:?} ==",
        opts.clients, opts.requests, opts.workers, cfg.k, cfg.r, WIDTHS
    );

    let interval = Duration::from_secs_f64(opts.clients as f64 / opts.rate);
    let (results, wall, metrics_json) = if opts.wire {
        let server = WireServer::start(&cfg, "127.0.0.1:0", opts.workers)?;
        let addr = server.local_addr();
        let layout = wire_layout(&cfg)?;
        let t0 = Instant::now();
        let results: Vec<Result<ClientResult>> = std::thread::scope(|s| {
            let handles: Vec<_> = pools
                .iter()
                .enumerate()
                .map(|(c, pool)| {
                    let oracle = &oracle;
                    s.spawn(move || run_wire(addr, layout, c as u64, pool, oracle))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let wall = t0.elapsed();
        let mj = server.metrics().to_json();
        server.shutdown();
        (results, wall, mj)
    } else if let Some(kind) = opts.peer {
        // No service in between: each client drives full peer
        // collectives through a shared plan cache.
        let job = EncodeJob::synthetic(cfg.clone())?;
        let cache = PlanCache::new();
        let t0 = Instant::now();
        let results: Vec<Result<ClientResult>> = std::thread::scope(|s| {
            let handles: Vec<_> = pools
                .iter()
                .map(|pool| {
                    let (job, cache, oracle) = (&job, &cache, &oracle);
                    s.spawn(move || run_peer_loop(job, cache, kind, pool, oracle))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let wall = t0.elapsed();
        (results, wall, "{}".to_string())
    } else {
        let svc = if opts.faults > 0 {
            // Crash `faults` sink processes post-run (storage loss):
            // every response must still carry all R rows, repaired from
            // the surviving coordinates.
            let spec = (0..opts.faults).fold(FaultSpec::new(), |s, i| s.crash_after(cfg.k + i));
            EncodeService::start_degraded(&cfg, opts.workers, cfg.serve.queue_depth, spec)?
        } else {
            EncodeService::start_replay(&cfg, opts.workers, cfg.serve.queue_depth)?
        };
        let open_loop = opts.open_loop;
        let t0 = Instant::now();
        let results: Vec<Result<ClientResult>> = std::thread::scope(|s| {
            let handles: Vec<_> = pools
                .iter()
                .enumerate()
                .map(|(c, pool)| {
                    let (svc, oracle) = (&svc, &oracle);
                    s.spawn(move || {
                        if open_loop {
                            run_open(svc, c as u64, pool, interval, oracle)
                        } else {
                            run_closed(svc, c as u64, pool, oracle)
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let wall = t0.elapsed();
        let mj = svc.metrics.to_json();
        svc.shutdown();
        (results, wall, mj)
    };

    let mut lats: Vec<u64> = Vec::new();
    let (mut rejects, mut failures) = (0u64, 0u64);
    let mut match_direct = true;
    for r in results {
        let r = r?;
        lats.extend(r.lats);
        rejects += r.rejects;
        failures += r.failures;
        match_direct &= r.match_direct;
    }
    lats.sort_unstable();
    let completed = lats.len();
    let offered = opts.clients * opts.requests;
    let throughput = completed as f64 / wall.as_secs_f64();
    let (p50, p99, p999) = (pct(&lats, 0.50), pct(&lats, 0.99), pct(&lats, 0.999));
    let max = lats.last().copied().unwrap_or(0);

    println!(
        "completed {completed}/{offered} in {wall:?} — {throughput:.1} req/s \
         ({rejects} shed, {failures} failed)"
    );
    println!("latency µs: p50={p50} p99={p99} p999={p999} max={max}");
    println!(
        "responses match direct encode path: {}",
        if match_direct { "yes" } else { "NO" }
    );
    println!("metrics: {metrics_json}");
    anyhow::ensure!(match_direct, "served bytes diverged from the direct path");
    anyhow::ensure!(failures == 0, "{failures} requests failed");

    if let Some(path) = &opts.json {
        let report = format!(
            concat!(
                "{{\"bench\": \"loadgen\", \"mode\": \"{mode}\", \"front\": \"{front}\", ",
                "\"clients\": {clients}, \"requests_per_client\": {rpc}, ",
                "\"completed\": {completed}, \"rejected\": {rejects}, ",
                "\"failures\": {failures}, \"faults\": {faults}, ",
                "\"responses_match_direct\": {md}, ",
                "\"throughput_req_per_s\": {thr:.1}, ",
                "\"p50_us\": {p50}, \"p99_us\": {p99}, \"p999_us\": {p999}, ",
                "\"max_us\": {max}}}\n"
            ),
            mode = mode,
            front = front,
            clients = opts.clients,
            rpc = opts.requests,
            completed = completed,
            rejects = rejects,
            failures = failures,
            faults = opts.faults,
            md = match_direct,
            thr = throughput,
            p50 = p50,
            p99 = p99,
            p999 = p999,
            max = max,
        );
        std::fs::write(path, report).with_context(|| format!("writing {path}"))?;
        println!("report written to {path}");
    }
    Ok(())
}

//! Peer-to-peer encode across **real processes** over TCP.
//!
//! The parent re-execs itself once per participant (`--rank i`); each
//! child holds only its own [`PlanShard`] — its inputs, its slice of
//! the schedule — and executes the collective against a
//! [`TcpTransport`] mesh on loopback. No process ever sees the full
//! state: the paper's "no central processor" model, made literal with
//! process isolation instead of threads.
//!
//! Rendezvous is pipe-based: every child binds `127.0.0.1:0`, prints
//! `ADDR <proc> <addr>` on stdout, and the parent relays the complete
//! address table to every child's stdin. Children then form the mesh
//! (dial down, accept up), run their rounds, and report `OUT` /
//! `STATS` lines. The parent cross-checks both against an in-process
//! peer run of the same plan:
//!
//! * every rank's output packet must be **bit-identical**, and
//! * the merged **measured** traffic (rounds, per-round maxima,
//!   messages, bandwidth) must agree exactly — two independent
//!   executions of one schedule can't disagree on what they shipped.
//!
//! ```bash
//! cargo run --release --example peer_encode
//! cargo run --release --example peer_encode -- --k 16 --r 4 --w 32
//! ```

use anyhow::{Context, Result};
use dce::prelude::*;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener};
use std::process::{Command, Stdio};
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(20);

/// One shared config for parent and children — must be identical so
/// every process derives the same plan and the same synthetic inputs.
fn config(args: &[String]) -> Result<JobConfig> {
    let mut cfg = JobConfig {
        k: 8,
        r: 4,
        w: 16,
        ..JobConfig::default()
    };
    let mut it = args.iter();
    while let Some(flag) = it.next() {
        let mut val = || -> Result<&String> {
            it.next().with_context(|| format!("{flag} needs a value"))
        };
        match flag.as_str() {
            "--k" => cfg.k = val()?.parse()?,
            "--r" => cfg.r = val()?.parse()?,
            "--w" => cfg.w = val()?.parse()?,
            "--field" => cfg.field = val()?.clone(),
            "--algorithm" => cfg.algorithm = val()?.parse()?,
            other => anyhow::bail!("unknown flag {other:?}"),
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Everything a process needs to know about the collective, derived
/// deterministically from the config (so parent and children agree
/// without shipping the plan over a pipe).
fn sharded(cfg: &JobConfig) -> Result<(EncodeJob, ShardedPlan)> {
    let job = EncodeJob::synthetic(cfg.clone())?;
    let cache = PlanCache::new();
    let compiled = job.compiled(&cache)?;
    let owners: Vec<ProcId> = (0..compiled.plan.n_inputs).collect();
    let plan_shards = ShardedPlan::new(&compiled.plan, &job.field, &owners)?;
    Ok((job, plan_shards))
}

/// Child: bind, rendezvous over stdin/stdout, execute one shard.
fn child(rank_ix: usize, cfg_args: &[String]) -> Result<()> {
    let cfg = config(cfg_args)?;
    let (job, sharded) = sharded(&cfg)?;
    let shard = &sharded.shards[rank_ix];
    let proc = sharded.procs[rank_ix];

    let listener = TcpListener::bind("127.0.0.1:0")?;
    println!("ADDR {} {}", proc, listener.local_addr()?);
    std::io::stdout().flush()?;

    // The parent relays every participant's line back to us.
    let stdin = std::io::stdin();
    let mut addrs: Vec<(ProcId, SocketAddr)> = Vec::new();
    for line in stdin.lock().lines() {
        let line = line?;
        let mut parts = line.split_whitespace();
        match (parts.next(), parts.next()) {
            (Some(p), Some(a)) => addrs.push((p.parse()?, a.parse()?)),
            _ => anyhow::bail!("malformed address line {line:?}"),
        }
        if addrs.len() == sharded.procs.len() {
            break;
        }
    }

    let mut transport = TcpTransport::connect(proc, listener, &addrs, TIMEOUT)?;
    let my_inputs: Vec<Packet> = shard.owned.iter().map(|&k| job.inputs[k].clone()).collect();
    let (out, stats) = execute_shard(shard, &job.field, cfg.w, &my_inputs, &mut transport)?;

    if let Some(pkt) = out {
        let words: Vec<String> = pkt.iter().map(|v| v.to_string()).collect();
        println!("OUT {} {}", proc, words.join(","));
    }
    let permax: Vec<String> = stats.per_round_sent_max.iter().map(|v| v.to_string()).collect();
    println!(
        "STATS {} rounds={} messages={} elems={} permax={}",
        proc,
        stats.rounds,
        stats.messages,
        stats.elems,
        permax.join(",")
    );
    Ok(())
}

fn parent(cfg_args: &[String]) -> Result<()> {
    let cfg = config(cfg_args)?;
    let (job, sharded) = sharded(&cfg)?;
    let n = sharded.procs.len();
    println!(
        "== peer_encode: {} processes over TCP, K={} R={} W={} ==",
        n, cfg.k, cfg.r, cfg.w
    );

    let exe = std::env::current_exe()?;
    let mut children = Vec::with_capacity(n);
    for rank_ix in 0..n {
        let mut cmd = Command::new(&exe);
        cmd.arg("--rank").arg(rank_ix.to_string()).args(cfg_args);
        cmd.stdin(Stdio::piped()).stdout(Stdio::piped());
        let mut ch = cmd.spawn().with_context(|| format!("spawning rank {rank_ix}"))?;
        let stdout = BufReader::new(ch.stdout.take().expect("piped stdout"));
        children.push((ch, stdout));
    }

    // Collect every child's ADDR line, then relay the full table.
    let mut addr_lines = Vec::with_capacity(n);
    for (_, stdout) in children.iter_mut() {
        let mut line = String::new();
        stdout.read_line(&mut line)?;
        let rest = line
            .trim()
            .strip_prefix("ADDR ")
            .with_context(|| format!("expected ADDR line, got {line:?}"))?;
        addr_lines.push(rest.to_string());
    }
    for (ch, _) in children.iter_mut() {
        let stdin = ch.stdin.as_mut().expect("piped stdin");
        for l in &addr_lines {
            writeln!(stdin, "{l}")?;
        }
        stdin.flush()?;
    }

    // Drain OUT/STATS lines and wait for clean exits.
    let mut outputs: std::collections::BTreeMap<ProcId, Packet> = Default::default();
    let mut stats: Vec<PeerStats> = Vec::new();
    for (ch, stdout) in children.iter_mut() {
        for line in stdout.lines() {
            let line = line?;
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("OUT") => {
                    let proc: ProcId = parts.next().context("OUT proc")?.parse()?;
                    let pkt: Packet = parts
                        .next()
                        .context("OUT payload")?
                        .split(',')
                        .map(|v| v.parse::<u64>().map_err(Into::into))
                        .collect::<Result<_>>()?;
                    outputs.insert(proc, pkt);
                }
                Some("STATS") => {
                    let _proc: ProcId = parts.next().context("STATS proc")?.parse()?;
                    let mut st = PeerStats::default();
                    for kv in parts {
                        let (k, v) = kv.split_once('=').context("STATS key=value")?;
                        match k {
                            "rounds" => st.rounds = v.parse()?,
                            "messages" => st.messages = v.parse()?,
                            "elems" => st.elems = v.parse()?,
                            "permax" if !v.is_empty() => {
                                st.per_round_sent_max = v
                                    .split(',')
                                    .map(|x| x.parse::<u64>().map_err(Into::into))
                                    .collect::<Result<_>>()?
                            }
                            _ => {}
                        }
                    }
                    stats.push(st);
                }
                _ => println!("  [child] {line}"),
            }
        }
        let status = ch.wait()?;
        anyhow::ensure!(status.success(), "a child rank failed: {status}");
    }

    // Oracle: the same sharded plan, in-process over channel transport.
    let oracle = spawn_local(
        &sharded,
        &job.field,
        &job.inputs,
        TransportKind::Channel,
        TIMEOUT,
    )?;
    let measured = merge_stats(sharded.n_rounds, &stats);
    println!(
        "measured: C1={} C2={} messages={} bandwidth={}",
        measured.c1, measured.c2, measured.messages, measured.bandwidth
    );
    anyhow::ensure!(
        outputs == oracle.outputs,
        "multi-process outputs diverge from in-process peer run"
    );
    anyhow::ensure!(
        measured == oracle.measured,
        "multi-process measured traffic diverges: {measured:?} vs {:?}",
        oracle.measured
    );
    println!("processes agree with the in-process peer oracle: OK");
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) == Some("--rank") {
        let rank_ix: usize = args
            .get(1)
            .context("--rank needs a value")?
            .parse()?;
        child(rank_ix, &args[2..])
    } else {
        parent(&args)
    }
}

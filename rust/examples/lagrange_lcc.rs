//! Decentralized Lagrange coded computing (Remark 9 + Appendix B).
//!
//! The LCC workflow, master-less: `K = 8` data owners hold vectors; the
//! network decentrally encodes them with a *non-systematic* Lagrange code
//! onto `N = 24` workers (Appendix B framework — non-systematic so
//! workers do not learn raw data); every worker evaluates a quadratic
//! polynomial on its coded share; any `2(K−1)+1 = 15` worker results
//! reconstruct the true outputs, tolerating 9 stragglers.
//!
//! ```bash
//! cargo run --release --example lagrange_lcc
//! ```

use dce::prelude::*;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let f = GfPrime::default_field();
    let (k, n, w, ports) = (8usize, 24usize, 32usize, 1usize);
    // Non-systematic Lagrange code on *structured* points, so the §VI
    // specific algorithm applies to every worker block (Remark 9).
    let code = LagrangeCode::structured(&f, k, n, 2)?;
    let g = Arc::new(code.matrix(&f));

    let mut rng = Rng::new(7);
    let data: Vec<Packet> = (0..k)
        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
        .collect();

    println!("== decentralized LCC encode: {k} owners → {n} workers (App. B) ==");
    let mut job = NonSystematicEncode::new_lagrange(f, &code, data.clone(), ports)?;
    let report = run(&mut Sim::new(ports), &mut job)?;
    println!(
        "specific (Remark 9):  C1 = {} rounds, C2 = {} elems, bandwidth = {}",
        report.c1, report.c2, report.bandwidth
    );
    let mut univ = NonSystematicEncode::new(f, g.clone(), data.clone(), ports)?;
    let report_u = run(&mut Sim::new(ports), &mut univ)?;
    println!(
        "universal (App. B):   C1 = {} rounds, C2 = {} elems, bandwidth = {}",
        report_u.c1, report_u.c2, report_u.bandwidth
    );
    anyhow::ensure!(job.codeword() == univ.codeword(), "paths must agree");
    // All N coordinates are worker shares: g(β_n) for n ∈ [0, N). The
    // first K land at the owners (who double as workers for their own
    // share — they still never see each other's raw data), the rest at
    // the dedicated worker processors.
    let shares = job.codeword();

    // Workers compute h(z) = 3z² + z + 5 element-wise on their shares.
    let h = |z: u64| f.add(f.add(f.mul(3, f.mul(z, z)), z), 5);
    let results: Vec<Packet> = shares
        .iter()
        .map(|s| s.iter().map(|&z| h(z)).collect())
        .collect();

    // 9 stragglers drop out; decode from the 15 fastest.
    let need = 2 * (k - 1) + 1;
    println!("== decoding h(x) from {need} of {} workers (9 stragglers) ==", shares.len());
    let fast = rng.choose(shares.len(), need);
    let mut ok = true;
    for pos in [0usize, w - 1] {
        let per_worker: Vec<(usize, u64)> =
            fast.iter().map(|&i| (i, results[i][pos])).collect();
        let decoded = code.decode_computation(&f, 2, &per_worker)?;
        let want: Vec<u64> = data.iter().map(|x| h(x[pos])).collect();
        if decoded != want {
            ok = false;
            println!("sample {pos}: MISMATCH");
        }
    }
    println!("straggler-resilient decode: {}", if ok { "OK" } else { "FAILED" });
    anyhow::ensure!(ok, "LCC decode failed");
    Ok(())
}

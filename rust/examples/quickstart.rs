//! Quickstart: decentralized encoding of a systematic Reed–Solomon code
//! in a dozen lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dce::prelude::*;

fn main() -> anyhow::Result<()> {
    // A [N=20, K=16] systematic RS code over GF(786433), encoded by 16
    // sources + 4 sinks with 1 port each, payloads of 64 field elements.
    let cfg = JobConfig {
        k: 16,
        r: 4,
        w: 64,
        ports: 1,
        ..JobConfig::default()
    };

    println!("== planning & running the decentralized encode ==");
    let job = EncodeJob::synthetic(cfg)?;
    let report = job.run(&ExecOptions::new())?;
    println!("{report}\n");

    // What the numbers mean, in the paper's terms:
    println!("C1 (rounds)            : {}", report.sim.c1);
    println!("C2 (sequential elems)  : {}", report.sim.c2);
    println!("total bandwidth (elems): {}", report.sim.bandwidth);
    println!("linear-model cost C    : {:.2}", report.cost);

    // Compare against the universal algorithm on the same code.
    let mut cfg_u = job.config.clone();
    cfg_u.algorithm = "universal".parse()?;
    let report_u = EncodeJob::synthetic(cfg_u)?.run(&ExecOptions::new())?;
    println!(
        "\nuniversal on the same code: C1={} C2={} (specific: C1={} C2={})",
        report_u.sim.c1, report_u.sim.c2, report.sim.c1, report.sim.c2
    );
    Ok(())
}

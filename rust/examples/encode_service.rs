//! End-to-end driver: the batch-encode service on a real workload.
//!
//! Two serving engines, picked automatically:
//!
//! * **PJRT** (when `make artifacts` has run): the AOT-compiled Pallas
//!   GF(p) kernel executes every batch — Python is not running.
//! * **Plan replay** (no artifacts needed): the decentralized encoding
//!   schedule is compiled **once** into the Plan IR and replayed for
//!   every request — no per-request planning or round stepping. Watch
//!   `plan_cache_hits` / `plan_cache_misses` in the metrics dump.
//!
//! Either way the coordinator batches requests through a bounded queue
//! (backpressure), measures latency percentiles and throughput, and
//! cross-checks one batch against the *simulated decentralized
//! encoding* — proving the serving path and the protocol path agree.
//!
//! ```bash
//! cargo run --release --example encode_service          # plan replay
//! make artifacts && cargo run --release --example encode_service  # PJRT
//! ```

use dce::prelude::*;
use std::path::Path;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let f = GfPrime::default_field();
    let (k, r) = (64usize, 16usize);
    let artifacts = Path::new("artifacts");

    let code = GrsCode::structured(&f, k, r, 2)?;
    let parity = code.parity_matrix(&f);

    let svc = if artifacts.join("manifest.txt").exists() {
        println!("== starting PJRT encode service: K={k} R={r}, 4 workers ==");
        EncodeService::start(&f, &parity, artifacts, 256, 4, 32)?
    } else {
        println!("== starting plan-replay encode service: K={k} R={r}, 4 workers ==");
        let cfg = JobConfig {
            k,
            r,
            ..JobConfig::default()
        };
        EncodeService::start_replay(&cfg, 4, 32)?
    };

    // Workload: 64 batched requests of 64×512 payloads.
    let requests = 64usize;
    let w = 512usize;
    let mut rng = Rng::new(99);
    let batches: Vec<Vec<Vec<u64>>> = (0..requests)
        .map(|_| {
            (0..k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect()
        })
        .collect();

    let t0 = Instant::now();
    let pending: Vec<_> = batches
        .iter()
        .map(|x| svc.submit(x.clone()))
        .collect::<Result<_, _>>()?;
    let mut responses = Vec::new();
    for rx in pending {
        responses.push(rx.recv()?);
    }
    let wall = t0.elapsed();

    let ok = responses.iter().filter(|r| r.y.is_ok()).count();
    let elems = requests * k * w;
    println!(
        "served {ok}/{requests} batches in {wall:?} — {:.1} req/s, {:.2} Melem/s encoded",
        requests as f64 / wall.as_secs_f64(),
        elems as f64 / wall.as_secs_f64() / 1e6
    );
    if let Some((n, p50, p99, max)) = svc.metrics.latency_summary("encode_latency") {
        println!("latency (µs): n={n} p50={p50} p99={p99} max={max}");
    }

    // == cross-check one batch against the decentralized protocol ==
    println!("\n== verifying batch 0 against the simulated decentralized encode ==");
    let x0: Vec<Packet> = batches[0].clone();
    let mut sim_job = SystematicEncode::new_rs(f, &code, x0, 1)?;
    let report = run(&mut Sim::new(1), &mut sim_job)?;
    let sim_parities = sim_job.coded();
    let svc_parities = responses[0].y.as_ref().unwrap();
    anyhow::ensure!(
        (0..r).all(|j| sim_parities[j] == svc_parities[j]),
        "protocol path and serving path disagree!"
    );
    println!(
        "agreement OK (simulated C1 = {}, C2 = {} elems for the same batch)",
        report.c1, report.c2
    );
    println!("\nmetrics: {}", svc.metrics.to_json());
    svc.shutdown();
    Ok(())
}

//! The paper's motivating example (§I): a local sensor network.
//!
//! `K = 48` thermometers each hold `W = 256` readings; the network
//! decentrally encodes them with a `[64, 48]` systematic Reed–Solomon
//! code so that *any 48 of the 64 nodes* suffice to recover every
//! reading. The demo:
//!
//! 1. runs the decentralized encoding (specific §VI algorithm, p = 2),
//! 2. fails 16 random nodes and decodes from the survivors,
//! 3. prints measured `C1`/`C2` against the universal alternative.
//!
//! ```bash
//! cargo run --release --example sensor_network
//! ```

use dce::prelude::*;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let f = GfPrime::default_field();
    let (k, r, w, ports) = (48usize, 16usize, 256usize, 2usize);
    let code = GrsCode::structured(&f, k, r, 2)?;

    // Thermometer readings: W samples per sensor.
    let mut rng = Rng::new(2024);
    let readings: Vec<Packet> = (0..k)
        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
        .collect();

    println!("== decentralized encoding: {k} sensors, {r} parities, W={w}, p={ports} ==");
    let mut job = SystematicEncode::new_rs(f, &code, readings.clone(), ports)?;
    let report = run(&mut Sim::new(ports), &mut job)?;
    let parities = job.coded();
    println!(
        "specific (§VI):  C1 = {:>3} rounds, C2 = {:>6} elems, bandwidth = {} elems",
        report.c1, report.c2, report.bandwidth
    );

    let a = Arc::new(code.parity_matrix(&f));
    let mut univ =
        SystematicEncode::new(f, a, readings.clone(), ports, A2aAlgo::Universal)?;
    let report_u = run(&mut Sim::new(ports), &mut univ)?;
    println!(
        "universal (§IV): C1 = {:>3} rounds, C2 = {:>6} elems, bandwidth = {} elems",
        report_u.c1, report_u.c2, report_u.bandwidth
    );
    anyhow::ensure!(univ.coded() == parities, "algorithms must agree");

    // == node failures & decode-from-any-K ==
    println!("\n== failing {r} random nodes, decoding from any {k} ==");
    let codeword: Vec<Packet> = readings.iter().cloned().chain(parities).collect();
    let mut ok = true;
    for trial in 0..5 {
        let survivors = rng.choose(k + r, k);
        // Decode a few of the W sample positions independently.
        for pos in [0usize, w / 2, w - 1] {
            let coords: Vec<(usize, u64)> =
                survivors.iter().map(|&i| (i, codeword[i][pos])).collect();
            let decoded = code.decode(&f, &coords)?;
            let want: Vec<u64> = readings.iter().map(|x| x[pos]).collect();
            if decoded != want {
                ok = false;
                println!("trial {trial}: decode MISMATCH at sample {pos}");
            }
        }
    }
    println!(
        "decode from random {k}-subsets: {}",
        if ok { "all OK" } else { "FAILED" }
    );
    anyhow::ensure!(ok, "decoding failed");

    println!("MDS spot-check: {}", code.is_mds(&f, 30, 7));
    Ok(())
}

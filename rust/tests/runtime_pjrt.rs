//! Three-layer integration: the AOT-compiled Pallas/JAX artifact executed
//! from rust must agree with the native GF oracle and with the simulated
//! decentralized encoding.
//!
//! Requires `make artifacts` (skips gracefully when artifacts are absent
//! so plain `cargo test` works in a fresh checkout).

use dce::coordinator::{config::VerifyMode, EncodeJob, ExecOptions, JobConfig};
use dce::gf::{Field, GfPrime, Mat};
use dce::runtime::Runtime;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts/manifest.txt (run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_encoder_matches_native_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let f = GfPrime::default_field();
    let (k, r, w) = (16usize, 4usize, 64usize);
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let enc = rt
        .load_encoder(dir, k, r, w, f.order())
        .expect("encode artifact");
    let a = Mat::random(&f, k, r, 7);
    let x = Mat::random(&f, k, w, 8);
    let a_flat: Vec<u64> = (0..k).flat_map(|i| a.row(i).to_vec()).collect();
    let x_flat: Vec<u64> = (0..k).flat_map(|i| x.row(i).to_vec()).collect();
    let y = enc.encode_u64(&a_flat, &x_flat).expect("execute");
    // Native oracle: y[j*w + c] = Σ_i a[i][j]·x[i][c].
    for j in 0..r {
        for c in 0..w {
            let mut want = 0u64;
            for i in 0..k {
                want = f.add(want, f.mul(a[(i, j)], x[(i, c)]));
            }
            assert_eq!(y[j * w + c], want, "mismatch at ({j},{c})");
        }
    }
}

#[test]
fn full_job_with_pjrt_verification() {
    let Some(_) = artifacts_dir() else { return };
    let cfg = JobConfig {
        k: 16,
        r: 4,
        w: 64,
        verify: VerifyMode::Pjrt,
        ..JobConfig::default()
    };
    let rep = EncodeJob::synthetic(cfg).unwrap().run(&ExecOptions::new()).unwrap();
    assert_eq!(
        rep.verified,
        Some(true),
        "simulated decentralized encode must match the PJRT artifact"
    );
}

#[test]
fn scaled_encode_artifact_matches_cauchy_block_math() {
    // The fused L1 kernel computes exactly the Theorem-6 block product
    // Φ^{-1}·V_α^{-1}·V_β·Ψ applied to payloads… here verified against
    // the generic diag(pre)·Aᵀ·diag(post) native oracle.
    let Some(dir) = artifacts_dir() else { return };
    let f = GfPrime::default_field();
    let (k, r, w) = (16usize, 4usize, 64usize);
    let rt = Runtime::cpu().unwrap();
    let enc = rt
        .load_scaled_encoder(dir, k, r, w, f.order())
        .expect("scaled artifact");
    let a = Mat::random(&f, k, r, 21);
    let x = Mat::random(&f, k, w, 22);
    let pre: Vec<u64> = (1..=k as u64).map(|i| f.elem(i * 7)).collect();
    let post: Vec<u64> = (1..=r as u64).map(|i| f.elem(i * 13)).collect();
    let a_flat: Vec<u64> = (0..k).flat_map(|i| a.row(i).to_vec()).collect();
    let x_flat: Vec<u64> = (0..k).flat_map(|i| x.row(i).to_vec()).collect();
    let y = enc.encode_u64(&pre, &post, &a_flat, &x_flat).unwrap();
    for j in 0..r {
        for c in 0..w {
            let mut want = 0u64;
            for i in 0..k {
                want = f.add(want, f.mul(f.mul(pre[i], a[(i, j)]), x[(i, c)]));
            }
            want = f.mul(want, post[j]);
            assert_eq!(y[j * w + c], want, "({j},{c})");
        }
    }
}

#[test]
fn codeword_artifact_is_systematic() {
    let Some(dir) = artifacts_dir() else { return };
    let f = GfPrime::default_field();
    let (k, r, w) = (16usize, 4usize, 64usize);
    let rt = Runtime::cpu().unwrap();
    let manifest = dce::runtime::Manifest::load(dir).unwrap();
    let entry = manifest
        .find(dce::runtime::ArtifactKind::Codeword, k, r, w, f.order())
        .expect("codeword artifact");
    let exe = rt.load(&dir.join(&entry.file)).unwrap();
    let a = Mat::random(&f, k, r, 3);
    let x = Mat::random(&f, k, w, 4);
    let ai: Vec<i32> = (0..k).flat_map(|i| a.row(i).iter().map(|&v| v as i32).collect::<Vec<_>>()).collect();
    let xi: Vec<i32> = (0..k).flat_map(|i| x.row(i).iter().map(|&v| v as i32).collect::<Vec<_>>()).collect();
    let cw = exe
        .run_i32(&[(&ai, &[k as i64, r as i64]), (&xi, &[k as i64, w as i64])])
        .unwrap();
    assert_eq!(cw.len(), (k + r) * w);
    // Systematic prefix: first K rows are X itself.
    assert_eq!(&cw[..k * w], &xi[..]);
}

//! Transport conformance: one suite, all three substrates.
//!
//! Every test below sweeps [`TransportKind::ALL`] through the same
//! [`transport::mesh`] factory the peer executor uses, so the contract
//! is pinned *per implementation*, not just for the reference channel
//! substrate:
//!
//! * round-synchronous delivery — a frame tagged for the wrong round is
//!   a typed [`TransportError::OutOfOrder`] rejection, never buffered;
//! * wrong-port frames are [`TransportError::PortMismatch`];
//! * a dropped peer surfaces as `PeerClosed`/`Timeout` **bounded by the
//!   recv timeout**, never a hang — on sockets, rings, and channels;
//! * the TCP framing inherits the serving tier's hostile-input caps:
//!   raw adversarial headers are rejected before any allocation;
//! * hostile *timing* is typed too — a mid-frame hangup is `PeerClosed`,
//!   a connected-but-silent peer costs exactly one recv `Timeout`, and a
//!   timed-out barrier withdraws cleanly so a later retry converges.

use dce::net::payload::{Packet, FRAME_HEADER_LEN};
use dce::net::transport::{self, tcp::read_frame_from, Transport, TransportError, TransportKind};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

const PROCS: [usize; 3] = [0, 1, 2];
const FRAME_BYTES: usize = 1 << 12;

fn mesh(kind: TransportKind, timeout: Duration) -> Vec<Box<dyn Transport>> {
    transport::mesh(kind, &PROCS, 2, FRAME_BYTES, timeout).unwrap()
}

#[test]
fn ring_exchange_and_barriers_on_every_substrate() {
    for kind in TransportKind::ALL {
        let endpoints = mesh(kind, Duration::from_secs(5));
        let results: Vec<Vec<Packet>> = std::thread::scope(|s| {
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut t| {
                    s.spawn(move || {
                        let n = PROCS.len();
                        let rank = t.rank();
                        assert_eq!(t.peers(), PROCS.as_slice(), "{kind}: peers()");
                        let mut got = Vec::new();
                        // Two rounds of a rotating ring, two ports each:
                        // exercises round tags, port tags, and barriers.
                        for round in 0..2u32 {
                            let dst = (rank + 1 + round as usize) % n;
                            let src = (rank + n - 1 - round as usize) % n;
                            for port in 0..2u32 {
                                let payload =
                                    vec![vec![rank as u64, round as u64, port as u64, 42]];
                                t.send(round, port, dst, &payload).unwrap();
                            }
                            for port in 0..2u32 {
                                let rows = t.recv(round, port, src).unwrap();
                                assert_eq!(
                                    rows,
                                    vec![vec![src as u64, round as u64, port as u64, 42]],
                                    "{kind}: round {round} port {port} payload"
                                );
                                got.extend(rows);
                            }
                            t.barrier(round).unwrap();
                        }
                        got
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(results.len(), PROCS.len(), "{kind}");
    }
}

#[test]
fn wrong_round_is_rejected_not_buffered_on_every_substrate() {
    for kind in TransportKind::ALL {
        let mut endpoints = mesh(kind, Duration::from_secs(2));
        let mut t1 = endpoints.remove(1);
        let mut t0 = endpoints.remove(0);
        // A frame for round 7 arriving while the schedule expects round
        // 0 is a protocol violation (the schedule is known a priori).
        t0.send(7, 0, 1, &[vec![9, 9, 9]]).unwrap();
        match t1.recv(0, 0, 0) {
            Err(TransportError::OutOfOrder {
                peer: 0,
                expected_round: 0,
                got_round: 7,
            }) => {}
            other => panic!("{kind}: expected OutOfOrder, got {other:?}"),
        }
    }
}

#[test]
fn wrong_port_is_rejected_on_every_substrate() {
    for kind in TransportKind::ALL {
        let mut endpoints = mesh(kind, Duration::from_secs(2));
        let mut t1 = endpoints.remove(1);
        let mut t0 = endpoints.remove(0);
        t0.send(0, 3, 1, &[vec![1]]).unwrap();
        match t1.recv(0, 0, 0) {
            Err(TransportError::PortMismatch {
                peer: 0,
                round: 0,
                expected_port: 0,
                got_port: 3,
            }) => {}
            other => panic!("{kind}: expected PortMismatch, got {other:?}"),
        }
    }
}

#[test]
fn dropped_peer_is_typed_and_bounded_on_every_substrate() {
    let timeout = Duration::from_millis(300);
    for kind in TransportKind::ALL {
        let mut endpoints = mesh(kind, timeout);
        let t2 = endpoints.remove(2);
        let t1 = endpoints.remove(1);
        let mut t0 = endpoints.remove(0);
        drop(t1); // rank 1 dies before sending anything
        drop(t2);
        let t0_start = Instant::now();
        match t0.recv(0, 0, 1) {
            // Which typed error depends on when the substrate learns of
            // the death (a closed channel/ring/socket vs. pure silence),
            // but it must be one of the two — and it must be *bounded*.
            Err(TransportError::PeerClosed { peer: 1, .. })
            | Err(TransportError::Timeout { peer: 1, .. }) => {}
            other => panic!("{kind}: expected PeerClosed/Timeout, got {other:?}"),
        }
        assert!(
            t0_start.elapsed() < Duration::from_secs(10),
            "{kind}: recv from a dead peer must be bounded by the timeout"
        );
    }
}

#[test]
fn barrier_with_an_absent_peer_times_out_on_every_substrate() {
    let timeout = Duration::from_millis(300);
    for kind in TransportKind::ALL {
        let mut endpoints = mesh(kind, timeout);
        let _t2 = endpoints.remove(2); // alive but never enters the barrier
        let _t1 = endpoints.remove(1);
        let mut t0 = endpoints.remove(0);
        let t0_start = Instant::now();
        match t0.barrier(0) {
            Err(TransportError::Timeout { .. }) | Err(TransportError::PeerClosed { .. }) => {}
            Ok(()) => panic!("{kind}: barrier completed without the other ranks"),
            Err(other) => panic!("{kind}: expected Timeout, got {other:?}"),
        }
        assert!(
            t0_start.elapsed() < Duration::from_secs(10),
            "{kind}: a missed barrier must be bounded by the timeout"
        );
    }
}

/// Aim raw hostile bytes at the exact read path `TcpTransport::recv`
/// uses. The serving tier's header caps must reject each frame before
/// any payload allocation happens.
type HeaderMutation = Box<dyn Fn(&mut [u8; FRAME_HEADER_LEN]) + Send>;

#[test]
fn tcp_rejects_hostile_framed_headers() {
    // (mutation, expected substring in the typed Frame error)
    let cases: Vec<(&str, HeaderMutation)> = vec![
        (
            "bad frame magic",
            Box::new(|h| h[0..4].copy_from_slice(b"EVIL")),
        ),
        (
            "too large", // rows far beyond MAX_FRAME_DIM
            Box::new(|h| h[24..28].copy_from_slice(&(1u32 << 30).to_le_bytes())),
        ),
        (
            "too large", // payload_len beyond MAX_FRAME_PAYLOAD
            Box::new(|h| h[32..36].copy_from_slice(&u32::MAX.to_le_bytes())),
        ),
        (
            "does not match", // rows×width disagrees with payload_len
            Box::new(|h| h[24..28].copy_from_slice(&7u32.to_le_bytes())),
        ),
    ];
    for (expect, mutate) in cases {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let attacker = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // Start from a well-formed header for 1×1 u64 rows...
            let mut h = [0u8; FRAME_HEADER_LEN];
            h[0..4].copy_from_slice(b"DCE1");
            h[4] = 2; // Request
            h[5] = 8; // u64 lane
            h[24..28].copy_from_slice(&1u32.to_le_bytes()); // rows
            h[28..32].copy_from_slice(&1u32.to_le_bytes()); // width
            h[32..36].copy_from_slice(&8u32.to_le_bytes()); // payload_len
            // ...then break exactly one invariant.
            mutate(&mut h);
            s.write_all(&h).unwrap();
            s
        });
        let (mut victim, _) = listener.accept().unwrap();
        let err = read_frame_from(&mut victim, 0, 0, Duration::from_secs(2)).unwrap_err();
        match err {
            TransportError::Frame { detail, .. } => assert!(
                detail.contains(expect),
                "expected {expect:?} in {detail:?}"
            ),
            other => panic!("expected Frame error, got {other:?}"),
        }
        drop(attacker.join().unwrap());
    }
}

/// A hostile *victim-side* variant: the peer closes mid-header. The
/// reader must surface `PeerClosed`, not block or return garbage.
#[test]
fn tcp_truncated_header_is_peer_closed() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let attacker = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"DCE1").unwrap(); // 4 of 40 header bytes, then hang up
        drop(s);
    });
    let (mut victim, _) = listener.accept().unwrap();
    let t0 = Instant::now();
    let err = read_frame_from(&mut victim, 3, 0, Duration::from_secs(2)).unwrap_err();
    match err {
        TransportError::PeerClosed { peer: 3, .. } => {}
        other => panic!("expected PeerClosed, got {other:?}"),
    }
    assert!(t0.elapsed() < Duration::from_secs(10));
    attacker.join().unwrap();
}

/// A peer that dies *mid-payload* — valid header, half the rows, then a
/// hangup — must surface `PeerClosed`: no garbage rows, no hang.
#[test]
fn tcp_mid_frame_reset_is_peer_closed_and_bounded() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let attacker = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut h = [0u8; FRAME_HEADER_LEN];
        h[0..4].copy_from_slice(b"DCE1");
        h[4] = 2; // Request
        h[5] = 8; // u64 lane
        h[24..28].copy_from_slice(&1u32.to_le_bytes()); // rows
        h[28..32].copy_from_slice(&2u32.to_le_bytes()); // width
        h[32..36].copy_from_slice(&16u32.to_le_bytes()); // payload_len
        s.write_all(&h).unwrap();
        s.write_all(&42u64.to_le_bytes()).unwrap(); // 8 of 16 bytes...
        drop(s); // ...then the connection dies mid-frame
    });
    let (mut victim, _) = listener.accept().unwrap();
    let t0 = Instant::now();
    let err = read_frame_from(&mut victim, 5, 0, Duration::from_secs(2)).unwrap_err();
    match err {
        TransportError::PeerClosed { peer: 5, .. } => {}
        other => panic!("expected PeerClosed, got {other:?}"),
    }
    assert!(t0.elapsed() < Duration::from_secs(10));
    attacker.join().unwrap();
}

/// A peer that connects and then goes silent costs exactly one recv
/// timeout — a typed `Timeout` carrying the round, never a hang and
/// never a misdiagnosed `PeerClosed`.
#[test]
fn tcp_connected_but_silent_peer_is_a_typed_timeout() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let _silent = TcpStream::connect(addr).unwrap(); // never writes
    let (mut victim, _) = listener.accept().unwrap();
    let t0 = Instant::now();
    let err = read_frame_from(&mut victim, 7, 4, Duration::from_millis(300)).unwrap_err();
    match err {
        TransportError::Timeout { peer: 7, round: 4, .. } => {}
        other => panic!("expected Timeout, got {other:?}"),
    }
    let waited = t0.elapsed();
    assert!(
        waited >= Duration::from_millis(250),
        "a silent peer must cost one full recv timeout: {waited:?}"
    );
    assert!(waited < Duration::from_secs(10));
}

/// A barrier against peers that died outright (endpoints dropped before
/// arriving) is a typed, bounded failure on every substrate.
#[test]
fn barrier_against_dead_peers_is_typed_and_bounded_on_every_substrate() {
    let timeout = Duration::from_millis(300);
    for kind in TransportKind::ALL {
        let mut endpoints = mesh(kind, timeout);
        drop(endpoints.remove(2)); // both other ranks die outright
        drop(endpoints.remove(1));
        let mut t0 = endpoints.remove(0);
        let t0_start = Instant::now();
        match t0.barrier(0) {
            Err(TransportError::Timeout { .. }) | Err(TransportError::PeerClosed { .. }) => {}
            Ok(()) => panic!("{kind}: barrier completed against dead peers"),
            Err(other) => panic!("{kind}: expected Timeout/PeerClosed, got {other:?}"),
        }
        assert!(
            t0_start.elapsed() < Duration::from_secs(10),
            "{kind}: a dead-peer barrier must be bounded by the timeout"
        );
    }
}

/// The regression pinned here: a barrier that times out must withdraw
/// cleanly — a later retry by the same rank (once the stragglers show
/// up) converges, and the *next* round's barrier still works. This
/// exercises the identified-arrival bookkeeping on channels and rings
/// and the send-resume state on sockets.
#[test]
fn barrier_timeout_then_retry_converges_on_every_substrate() {
    for kind in TransportKind::ALL {
        let mut endpoints = mesh(kind, Duration::from_millis(500));
        let t2 = endpoints.remove(2);
        let t1 = endpoints.remove(1);
        let mut t0 = endpoints.remove(0);
        // Rank 0 reaches the barrier alone and times out...
        match t0.barrier(0) {
            Err(TransportError::Timeout { .. }) => {}
            Ok(()) => panic!("{kind}: lone barrier completed"),
            Err(other) => panic!("{kind}: expected Timeout, got {other:?}"),
        }
        // ...then the stragglers arrive and everyone retries.
        let joiners = [t1, t2].map(|mut t| {
            std::thread::spawn(move || {
                t.barrier(0).unwrap();
                t.barrier(1).unwrap();
                t
            })
        });
        if let Err(e) = t0.barrier(0) {
            panic!("{kind}: retry after a timed-out barrier: {e}");
        }
        if let Err(e) = t0.barrier(1) {
            panic!("{kind}: follow-up barrier after recovery: {e}");
        }
        for j in joiners {
            j.join().unwrap();
        }
    }
}

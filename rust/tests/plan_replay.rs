//! Plan compile/replay conformance: for every A2A variant, over both
//! field families, across degenerate shapes, a compiled plan replayed
//! against fresh payloads must be **bit-identical** to live `Sim::run`
//! stepping — same outputs, same `(C1, C2)` (indeed the same full
//! [`SimReport`]), same wire trace.
//!
//! Property-style (seeded random sweeps, no proptest offline): each
//! shape's plan is compiled once and replayed against several random
//! payload sets, mirroring the cache's repeated-same-shape serving
//! pattern.

use dce::codes::{structured::disjoint_family, StructuredPoints};
use dce::collectives::{CauchyA2A, DftA2A, DrawLoose, MultiReduce, PrepareShoot};
use dce::coordinator::{EncodeJob, ExecOptions, JobConfig, PlanCache};
use dce::framework::{A2aAlgo, AlgoRequest, SystematicEncode};
use dce::gf::{Field, Gf2e, GfPrime, Mat};
use dce::net::{exec, plan, run, Collective, Packet, Sim};
use dce::util::{ipow, Rng};
use std::sync::Arc;

fn rand_inputs<F: Field>(f: &F, k: usize, w: usize, rng: &mut Rng) -> Vec<Packet> {
    (0..k)
        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
        .collect()
}

/// Compile the collective once, then check several fresh payload sets:
/// replay must match live stepping bit-for-bit (outputs + report +
/// trace), for both the output-only and the full-wire executor.
fn assert_replay_matches<F, B>(tag: &str, f: &F, ports: usize, k: usize, w: usize, build: B)
where
    F: Field,
    B: Fn(Vec<Packet>) -> Box<dyn Collective>,
{
    let compiled = plan::compile(ports, k, |basis| Ok(build(basis))).unwrap();
    let mut rng = Rng::new(k as u64 * 1009 + ports as u64 * 31 + w as u64);
    for trial in 0..3 {
        let inputs = rand_inputs(f, k, w, &mut rng);
        let mut live = build(inputs.clone());
        let mut sim = Sim::with_trace(ports);
        let live_report = run(&mut sim, live.as_mut()).unwrap();
        let live_outputs = live.outputs();

        let rep = exec::replay(&compiled, f, &inputs).unwrap();
        assert_eq!(rep.outputs, live_outputs, "{tag} trial {trial}: outputs");
        assert_eq!(rep.report, live_report, "{tag} trial {trial}: report");
        assert_eq!(
            (rep.report.c1, rep.report.c2),
            (live_report.c1, live_report.c2),
            "{tag} trial {trial}: (C1, C2)"
        );

        let full = exec::replay_full(&compiled, f, &inputs).unwrap();
        assert_eq!(full.outputs, live_outputs, "{tag} trial {trial}: full outputs");
        assert_eq!(full.trace, sim.trace, "{tag} trial {trial}: wire trace");
    }
}

#[test]
fn prepare_shoot_prime_field_including_degenerate() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xA11);
    for (k, p, w) in [
        (1usize, 1usize, 1usize), // fully degenerate
        (2, 1, 1),
        (5, 1, 1),
        (16, 1, 4),
        (25, 2, 3),
        (10, 2, 1),
        (100, 4, 2),
    ] {
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let c2 = c.clone();
        assert_replay_matches(&format!("ps K={k} p={p} w={w}"), &f, p, k, w, move |ins| {
            Box::new(PrepareShoot::new(f, (0..k).collect(), p, c2.clone(), ins))
        });
    }
}

#[test]
fn prepare_shoot_gf2e_including_degenerate() {
    let f = Gf2e::new(8).unwrap();
    let mut rng = Rng::new(0xA12);
    for (k, p, w) in [(1usize, 1usize, 1usize), (13, 2, 3), (16, 1, 2), (40, 3, 1)] {
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let ff = f.clone();
        assert_replay_matches(&format!("ps/gf2e K={k} p={p} w={w}"), &f, p, k, w, move |ins| {
            Box::new(PrepareShoot::new(
                ff.clone(),
                (0..k).collect(),
                p,
                c.clone(),
                ins,
            ))
        });
    }
}

#[test]
fn multireduce_baseline_replays_identically() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xA13);
    for (k, p, w) in [(16usize, 1usize, 1usize), (27, 2, 2), (1, 1, 1)] {
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let c2 = c.clone();
        assert_replay_matches(&format!("mr K={k} p={p} w={w}"), &f, p, k, w, move |ins| {
            Box::new(MultiReduce::new(f, (0..k).collect(), p, c2.clone(), ins))
        });
    }
}

#[test]
fn dft_a2a_both_fields() {
    let f = GfPrime::default_field();
    for (p_base, h, p, w) in [(2u64, 3u32, 1usize, 1usize), (4, 2, 3, 2), (2, 4, 1, 3)] {
        let k = ipow(p_base, h) as usize;
        assert_replay_matches(
            &format!("dft P={p_base} H={h} p={p}"),
            &f,
            p,
            k,
            w,
            move |ins| {
                Box::new(DftA2A::new(f, (0..k).collect(), p, p_base, h, ins, false).unwrap())
            },
        );
    }
    // GF(256): q−1 = 255 = 3·5·17 — prime radixes only (H = 1 each).
    let f = Gf2e::new(8).unwrap();
    for (p_base, p, w) in [(3u64, 2usize, 2usize), (5, 2, 1), (17, 2, 2)] {
        let k = p_base as usize;
        let ff = f.clone();
        assert_replay_matches(
            &format!("dft/gf2e P={p_base} p={p}"),
            &f,
            p,
            k,
            w,
            move |ins| {
                Box::new(
                    DftA2A::new(ff.clone(), (0..k).collect(), p, p_base, 1, ins, false).unwrap(),
                )
            },
        );
    }
}

#[test]
fn draw_loose_both_fields_and_inverse() {
    let f = GfPrime::default_field();
    for (n, p_base, p, w, invert) in [
        (8usize, 2u64, 1usize, 1usize, false),
        (24, 2, 1, 2, false),
        (12, 2, 3, 1, false),
        (24, 2, 1, 1, true),
        (5, 2, 1, 2, false), // H = 0 fallback (Remark 8)
    ] {
        let hmax = StructuredPoints::max_h(&f, n as u64, p_base);
        let m = n / ipow(p_base, hmax) as usize;
        let sp = StructuredPoints::new(&f, n, p_base, (0..m as u64).collect()).unwrap();
        assert_replay_matches(
            &format!("dl n={n} P={p_base} p={p} inv={invert}"),
            &f,
            p,
            n,
            w,
            move |ins| {
                Box::new(DrawLoose::new(f, (0..n).collect(), p, &sp, ins, invert).unwrap())
            },
        );
    }
    // GF(256), radix 3: M = 2, Z = 3.
    let f = Gf2e::new(8).unwrap();
    let n = 6usize;
    let sp = StructuredPoints::new(&f, n, 3, vec![0, 1]).unwrap();
    let ff = f.clone();
    assert_replay_matches("dl/gf2e n=6", &f, 1, n, 2, move |ins| {
        Box::new(DrawLoose::new(ff.clone(), (0..n).collect(), 1, &sp, ins, false).unwrap())
    });
}

#[test]
fn cauchy_a2a_both_fields() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xCA2);
    for (n, p, w) in [(8usize, 1usize, 1usize), (16, 2, 2)] {
        let fam = disjoint_family(&f, n, 2, 2).unwrap();
        let pre: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
        let post: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
        assert_replay_matches(&format!("cauchy n={n} p={p}"), &f, p, n, w, move |ins| {
            Box::new(
                CauchyA2A::new(
                    f,
                    (0..n).collect(),
                    p,
                    &fam[0],
                    &fam[1],
                    pre.clone(),
                    post.clone(),
                    ins,
                )
                .unwrap(),
            )
        });
    }
    let f = Gf2e::new(8).unwrap();
    let n = 6usize;
    let fam = disjoint_family(&f, n, 3, 2).unwrap();
    let pre: Vec<u64> = (0..n).map(|_| rng.range(1, 256)).collect();
    let post: Vec<u64> = (0..n).map(|_| rng.range(1, 256)).collect();
    let ff = f.clone();
    assert_replay_matches("cauchy/gf2e n=6", &f, 1, n, 2, move |ins| {
        Box::new(
            CauchyA2A::new(
                ff.clone(),
                (0..n).collect(),
                1,
                &fam[0],
                &fam[1],
                pre.clone(),
                post.clone(),
                ins,
            )
            .unwrap(),
        )
    });
}

#[test]
fn systematic_framework_degenerate_shapes() {
    // The framework around the A2As, at the degenerate corners the
    // satellite names: K=1, R=1, p=1, W=1 (and small mixes).
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xDE6);
    for (k, r, p, w) in [
        (1usize, 1usize, 1usize, 1usize),
        (4, 1, 1, 1),
        (1, 4, 1, 1),
        (1, 1, 1, 3),
        (2, 2, 1, 1),
        (12, 4, 2, 2),
        (4, 12, 2, 2),
    ] {
        let a = Arc::new(Mat::random(&f, k, r, rng.next_u64()));
        let a2 = a.clone();
        assert_replay_matches(
            &format!("sys K={k} R={r} p={p} w={w}"),
            &f,
            p,
            k,
            w,
            move |ins| {
                Box::new(SystematicEncode::new(f, a2.clone(), ins, p, A2aAlgo::Universal).unwrap())
            },
        );
    }
}

#[test]
fn framework_compile_plan_replays_rs_specific() {
    // The full coordinator-facing compile path on the §VI specific
    // algorithm, checked against a live EncodeJob run per width.
    let cache = PlanCache::new();
    for w in [1usize, 4] {
        let cfg = JobConfig {
            k: 24,
            r: 8,
            w,
            algorithm: AlgoRequest::RsSpecific,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let live = job.run(&ExecOptions::new()).unwrap();
        let cached = job.run(&ExecOptions::cached(&cache)).unwrap();
        assert_eq!(cached.sim, live.sim, "w={w}");
        assert_eq!(cached.verified, Some(true), "w={w}");
    }
    // Width changes do not re-compile: one plan in the cache.
    assert_eq!(cache.len(), 1);
    assert_eq!(cache.stats(), (1, 1));
}

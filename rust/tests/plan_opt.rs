//! Optimizer conformance: for every A2A variant, over both field
//! families, across degenerate shapes and batch sizes, the optimized
//! plan — replayed one job at a time (`replay_opt`) or as one columnar
//! batch (`replay_batch`) — must be **bit-identical** to unoptimized
//! raw-plan `replay`, which in turn must be bit-identical to live
//! `Sim::run` stepping (outputs *and* report).
//!
//! Also asserts the pass-pipeline statics: the optimizer never grows a
//! plan, preserves the `SimReport` statics exactly, and at `N ≥ 64`
//! strictly shrinks every A2A variant (the wire-only prepare/butterfly
//! /draw intermediates are dead for serving).

use dce::codes::{structured::disjoint_family, StructuredPoints};
use dce::collectives::{CauchyA2A, DftA2A, DrawLoose, PrepareShoot};
use dce::framework::{A2aAlgo, SystematicEncode};
use dce::gf::{Field, Gf2e, GfPrime, IsaTier, Kernels, Mat};
use dce::net::{exec, opt, plan, run, Collective, Packet, Sim};
use dce::util::{ipow, Rng};
use std::sync::Arc;

const BATCH_SIZES: [usize; 3] = [1, 3, 32];

fn rand_inputs<F: Field>(f: &F, k: usize, w: usize, rng: &mut Rng) -> Vec<Packet> {
    (0..k)
        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
        .collect()
}

/// Compile + optimize the collective once, then for each batch size `B`:
/// `replay_batch` over `B` fresh payload sets must equal per-job raw
/// `replay` (outputs + report) bit for bit; job 0 additionally checks
/// `replay_opt` and a live `Sim::run` (outputs + report).
fn assert_opt_matches<F, B>(tag: &str, f: &F, ports: usize, k: usize, w: usize, build: B)
where
    F: Field,
    B: Fn(Vec<Packet>) -> Box<dyn Collective>,
{
    let compiled = plan::compile(ports, k, |basis| Ok(build(basis))).unwrap();
    let optimized = opt::optimize(&compiled);
    assert!(
        optimized.stats.slots_after <= optimized.stats.slots_before,
        "{tag}: optimizer grew the plan: {:?}",
        optimized.stats
    );
    assert_eq!(
        optimized.report(w),
        compiled.report(w),
        "{tag}: lowering changed the report statics"
    );

    let mut rng = Rng::new(k as u64 * 7817 + ports as u64 * 131 + w as u64);
    for b in BATCH_SIZES {
        let jobs: Vec<Vec<Packet>> = (0..b).map(|_| rand_inputs(f, k, w, &mut rng)).collect();
        let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();
        let batched = exec::replay_batch(&optimized, f, &refs).unwrap();
        assert_eq!(batched.len(), b, "{tag} B={b}: replay count");

        // The packed narrow-lane engine must agree with the unpacked
        // u64 reference engine bit for bit, for every variant, field
        // family, degenerate shape and batch size swept here.
        let scalar = exec::replay_batch_scalar(&optimized, f, &refs).unwrap();
        for (j, (bj, sj)) in batched.iter().zip(&scalar).enumerate() {
            assert_eq!(bj.outputs, sj.outputs, "{tag} B={b} job {j}: packed vs scalar");
            assert_eq!(bj.report, sj.report, "{tag} B={b} job {j}: packed vs scalar report");
        }

        for (j, x) in jobs.iter().enumerate() {
            let raw = exec::replay(&compiled, f, x).unwrap();
            assert_eq!(
                batched[j].outputs, raw.outputs,
                "{tag} B={b} job {j}: batch vs raw outputs"
            );
            assert_eq!(
                batched[j].report, raw.report,
                "{tag} B={b} job {j}: batch vs raw report"
            );
            if j == 0 {
                let single = exec::replay_opt(&optimized, f, x).unwrap();
                assert_eq!(single.outputs, raw.outputs, "{tag} B={b}: replay_opt outputs");
                assert_eq!(single.report, raw.report, "{tag} B={b}: replay_opt report");

                let mut live = build(x.clone());
                let live_report = run(&mut Sim::new(ports), live.as_mut()).unwrap();
                assert_eq!(raw.outputs, live.outputs(), "{tag} B={b}: raw vs live outputs");
                assert_eq!(raw.report, live_report, "{tag} B={b}: raw vs live report");
            }
        }
    }
}

/// Forced-tier conformance: compile + optimize once, take the u64
/// scalar engine as reference, then replay the same batch through
/// `replay_batch_kernels` under every *requested* ISA tier — scalar,
/// AVX2 and NEON. `Kernels` clamps a request the host cannot execute
/// down to scalar, so the sweep is safe everywhere while still pinning
/// the real SIMD backends wherever they exist. Outputs **and** report
/// must be bit-identical per tier.
fn assert_tiers_match<F, B>(tag: &str, f: &F, ports: usize, k: usize, build: B)
where
    F: Field,
    B: Fn(Vec<Packet>) -> Box<dyn Collective>,
{
    let compiled = plan::compile(ports, k, |basis| Ok(build(basis))).unwrap();
    let optimized = opt::optimize(&compiled);
    let mut rng = Rng::new(0x15A);
    let (b, w) = (4usize, 3usize);
    let jobs: Vec<Vec<Packet>> = (0..b).map(|_| rand_inputs(f, k, w, &mut rng)).collect();
    let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();
    let scalar = exec::replay_batch_scalar(&optimized, f, &refs).unwrap();
    for req in [IsaTier::Scalar, IsaTier::Avx2, IsaTier::Neon] {
        let kern = Kernels::for_field_with_isa(f, req);
        assert!(
            IsaTier::available().contains(&kern.isa()),
            "{tag}: request {req:?} resolved to non-executable {:?}",
            kern.isa()
        );
        let tiered = exec::replay_batch_kernels(&optimized, &kern, &refs).unwrap();
        for (j, (tj, sj)) in tiered.iter().zip(&scalar).enumerate() {
            assert_eq!(tj.outputs, sj.outputs, "{tag} {req:?} job {j}: outputs");
            assert_eq!(tj.report, sj.report, "{tag} {req:?} job {j}: report");
        }
    }
}

#[test]
fn forced_isa_tiers_replay_bit_identical_for_every_a2a_variant() {
    // Tentpole acceptance: all four A2A variants, both field families,
    // bit-identical across every requested kernel ISA tier.
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xB08);

    let k = 6usize;
    let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
    assert_tiers_match("ps/prime", &f, 2, k, move |ins| {
        Box::new(PrepareShoot::new(f, (0..k).collect(), 2, c.clone(), ins))
    });
    assert_tiers_match("dft/prime", &f, 1, 4, move |ins| {
        Box::new(DftA2A::new(f, (0..4).collect(), 1, 2, 2, ins, false).unwrap())
    });
    let n = 8usize;
    let hmax = StructuredPoints::max_h(&f, n as u64, 2);
    let m = n / ipow(2, hmax) as usize;
    let sp = StructuredPoints::new(&f, n, 2, (0..m as u64).collect()).unwrap();
    assert_tiers_match("dl/prime", &f, 1, n, move |ins| {
        Box::new(DrawLoose::new(f, (0..n).collect(), 1, &sp, ins, false).unwrap())
    });
    let fam = disjoint_family(&f, n, 2, 2).unwrap();
    let pre: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
    let post: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
    assert_tiers_match("cauchy/prime", &f, 1, n, move |ins| {
        Box::new(
            CauchyA2A::new(
                f,
                (0..n).collect(),
                1,
                &fam[0],
                &fam[1],
                pre.clone(),
                post.clone(),
                ins,
            )
            .unwrap(),
        )
    });

    let f = Gf2e::new(8).unwrap();
    let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
    let ff = f.clone();
    assert_tiers_match("ps/gf2e", &f, 2, k, move |ins| {
        Box::new(PrepareShoot::new(
            ff.clone(),
            (0..k).collect(),
            2,
            c.clone(),
            ins,
        ))
    });
    let ff = f.clone();
    assert_tiers_match("dft/gf2e", &f, 1, 3, move |ins| {
        Box::new(DftA2A::new(ff.clone(), (0..3).collect(), 1, 3, 1, ins, false).unwrap())
    });
    let n = 6usize;
    let sp = StructuredPoints::new(&f, n, 3, vec![0, 1]).unwrap();
    let ff = f.clone();
    assert_tiers_match("dl/gf2e", &f, 1, n, move |ins| {
        Box::new(DrawLoose::new(ff.clone(), (0..n).collect(), 1, &sp, ins, false).unwrap())
    });
    let fam = disjoint_family(&f, n, 3, 2).unwrap();
    let pre: Vec<u64> = (0..n).map(|_| rng.range(1, 256)).collect();
    let post: Vec<u64> = (0..n).map(|_| rng.range(1, 256)).collect();
    let ff = f.clone();
    assert_tiers_match("cauchy/gf2e", &f, 1, n, move |ins| {
        Box::new(
            CauchyA2A::new(
                ff.clone(),
                (0..n).collect(),
                1,
                &fam[0],
                &fam[1],
                pre.clone(),
                post.clone(),
                ins,
            )
            .unwrap(),
        )
    });
}

#[test]
fn prepare_shoot_prime_and_gf2e_including_degenerate() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xB01);
    for (k, p, w) in [
        (1usize, 1usize, 1usize), // fully degenerate
        (2, 1, 1),
        (16, 1, 4),
        (25, 2, 3),
        (100, 4, 2),
    ] {
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let c2 = c.clone();
        assert_opt_matches(&format!("ps K={k} p={p} w={w}"), &f, p, k, w, move |ins| {
            Box::new(PrepareShoot::new(f, (0..k).collect(), p, c2.clone(), ins))
        });
    }
    let f = Gf2e::new(8).unwrap();
    for (k, p, w) in [(1usize, 1usize, 1usize), (13, 2, 3), (40, 3, 1)] {
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let ff = f.clone();
        assert_opt_matches(
            &format!("ps/gf2e K={k} p={p} w={w}"),
            &f,
            p,
            k,
            w,
            move |ins| {
                Box::new(PrepareShoot::new(
                    ff.clone(),
                    (0..k).collect(),
                    p,
                    c.clone(),
                    ins,
                ))
            },
        );
    }
}

#[test]
fn dft_a2a_both_fields() {
    let f = GfPrime::default_field();
    for (p_base, h, p, w) in [(2u64, 3u32, 1usize, 1usize), (4, 2, 3, 2), (2, 4, 1, 3)] {
        let k = ipow(p_base, h) as usize;
        assert_opt_matches(
            &format!("dft P={p_base} H={h} p={p}"),
            &f,
            p,
            k,
            w,
            move |ins| {
                Box::new(DftA2A::new(f, (0..k).collect(), p, p_base, h, ins, false).unwrap())
            },
        );
    }
    // GF(256): q−1 = 255 = 3·5·17 — prime radixes only (H = 1 each).
    let f = Gf2e::new(8).unwrap();
    for (p_base, p, w) in [(3u64, 2usize, 2usize), (17, 2, 1)] {
        let k = p_base as usize;
        let ff = f.clone();
        assert_opt_matches(
            &format!("dft/gf2e P={p_base} p={p}"),
            &f,
            p,
            k,
            w,
            move |ins| {
                Box::new(
                    DftA2A::new(ff.clone(), (0..k).collect(), p, p_base, 1, ins, false).unwrap(),
                )
            },
        );
    }
}

#[test]
fn draw_loose_both_fields() {
    let f = GfPrime::default_field();
    for (n, p_base, p, w, invert) in [
        (8usize, 2u64, 1usize, 1usize, false),
        (24, 2, 1, 2, false),
        (24, 2, 1, 1, true),
        (5, 2, 1, 2, false), // H = 0 fallback
    ] {
        let hmax = StructuredPoints::max_h(&f, n as u64, p_base);
        let m = n / ipow(p_base, hmax) as usize;
        let sp = StructuredPoints::new(&f, n, p_base, (0..m as u64).collect()).unwrap();
        assert_opt_matches(
            &format!("dl n={n} P={p_base} p={p} inv={invert}"),
            &f,
            p,
            n,
            w,
            move |ins| {
                Box::new(DrawLoose::new(f, (0..n).collect(), p, &sp, ins, invert).unwrap())
            },
        );
    }
    let f = Gf2e::new(8).unwrap();
    let n = 6usize;
    let sp = StructuredPoints::new(&f, n, 3, vec![0, 1]).unwrap();
    let ff = f.clone();
    assert_opt_matches("dl/gf2e n=6", &f, 1, n, 2, move |ins| {
        Box::new(DrawLoose::new(ff.clone(), (0..n).collect(), 1, &sp, ins, false).unwrap())
    });
}

#[test]
fn cauchy_a2a_both_fields() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xB05);
    for (n, p, w) in [(8usize, 1usize, 1usize), (16, 2, 2)] {
        let fam = disjoint_family(&f, n, 2, 2).unwrap();
        let pre: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
        let post: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
        assert_opt_matches(&format!("cauchy n={n} p={p}"), &f, p, n, w, move |ins| {
            Box::new(
                CauchyA2A::new(
                    f,
                    (0..n).collect(),
                    p,
                    &fam[0],
                    &fam[1],
                    pre.clone(),
                    post.clone(),
                    ins,
                )
                .unwrap(),
            )
        });
    }
    let f = Gf2e::new(8).unwrap();
    let n = 6usize;
    let fam = disjoint_family(&f, n, 3, 2).unwrap();
    let pre: Vec<u64> = (0..n).map(|_| rng.range(1, 256)).collect();
    let post: Vec<u64> = (0..n).map(|_| rng.range(1, 256)).collect();
    let ff = f.clone();
    assert_opt_matches("cauchy/gf2e n=6", &f, 1, n, 2, move |ins| {
        Box::new(
            CauchyA2A::new(
                ff.clone(),
                (0..n).collect(),
                1,
                &fam[0],
                &fam[1],
                pre.clone(),
                post.clone(),
                ins,
            )
            .unwrap(),
        )
    });
}

#[test]
fn systematic_framework_degenerate_shapes() {
    // The framework around the A2As at the degenerate corners the
    // satellite names: K=1, R=1, p=1, W=1 (and small mixes).
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xB06);
    for (k, r, p, w) in [
        (1usize, 1usize, 1usize, 1usize),
        (4, 1, 1, 1),
        (1, 4, 1, 1),
        (1, 1, 1, 3),
        (12, 4, 2, 2),
        (4, 12, 2, 2),
    ] {
        let a = Arc::new(Mat::random(&f, k, r, rng.next_u64()));
        let a2 = a.clone();
        assert_opt_matches(
            &format!("sys K={k} R={r} p={p} w={w}"),
            &f,
            p,
            k,
            w,
            move |ins| {
                Box::new(SystematicEncode::new(f, a2.clone(), ins, p, A2aAlgo::Universal).unwrap())
            },
        );
    }
}

#[test]
fn every_a2a_variant_strictly_shrinks_at_n64() {
    // The acceptance claim: at N ≥ 64 every A2A variant carries
    // wire-only intermediate slots, so the optimized plan has strictly
    // fewer live slots than the raw plan.
    let f = GfPrime::default_field();
    let n = 64usize;
    let mut rng = Rng::new(0xB07);

    type Build = Box<dyn Fn(Vec<Packet>) -> Box<dyn Collective>>;
    let c = Arc::new(Mat::random(&f, n, n, rng.next_u64()));
    let c2 = c.clone();
    let mut variants: Vec<(&str, Build)> = vec![(
        "universal",
        Box::new(move |ins| {
            Box::new(PrepareShoot::new(f, (0..n).collect(), 1, c2.clone(), ins))
        }),
    )];
    variants.push((
        "dft",
        Box::new(move |ins| {
            Box::new(DftA2A::new(f, (0..n).collect(), 1, 2, 6, ins, false).unwrap())
        }),
    ));
    let hmax = StructuredPoints::max_h(&f, n as u64, 2);
    let m = n / ipow(2, hmax) as usize;
    let sp = StructuredPoints::new(&f, n, 2, (0..m as u64).collect()).unwrap();
    variants.push((
        "vandermonde",
        Box::new(move |ins| {
            Box::new(DrawLoose::new(f, (0..n).collect(), 1, &sp, ins, false).unwrap())
        }),
    ));
    let fam = disjoint_family(&f, n, 2, 2).unwrap();
    let pre: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
    let post: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
    variants.push((
        "cauchy",
        Box::new(move |ins| {
            Box::new(
                CauchyA2A::new(
                    f,
                    (0..n).collect(),
                    1,
                    &fam[0],
                    &fam[1],
                    pre.clone(),
                    post.clone(),
                    ins,
                )
                .unwrap(),
            )
        }),
    ));

    for (tag, build) in &variants {
        let compiled = plan::compile(1, n, |basis| Ok(build(basis))).unwrap();
        let optimized = opt::optimize(&compiled);
        assert!(
            optimized.stats.slots_after < optimized.stats.slots_before,
            "{tag} at N={n}: expected strict live-slot reduction, got {:?}",
            optimized.stats
        );
        assert!(optimized.stats.dead_lincombs > 0, "{tag}: {:?}", optimized.stats);
    }
}

#[test]
fn compiled_plan_carries_opt_and_cross_checked_sink_rows() {
    // The coordinator-facing path: every cached CompiledPlan stores the
    // optimized form, and its flattened sink rows equal the parity
    // columns (compile_plan cross-checks; re-assert here explicitly).
    use dce::coordinator::{EncodeJob, ExecOptions, JobConfig, PlanCache};
    use dce::framework::AlgoRequest;
    let cache = PlanCache::new();
    for algo in [
        AlgoRequest::Universal,
        AlgoRequest::RsSpecific,
        AlgoRequest::MultiReduce,
        AlgoRequest::Direct,
    ] {
        let cfg = JobConfig {
            k: 16,
            r: 4,
            w: 8,
            algorithm: algo,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let compiled = job.compiled(&cache).unwrap();
        for r in 0..compiled.layout.r {
            let row = compiled
                .opt
                .matrix
                .row_for(compiled.layout.sink(r))
                .expect("sink has a flattened row");
            for k in 0..compiled.layout.k {
                assert_eq!(row[k], job.parity[(k, r)], "{algo:?} sink {r} input {k}");
            }
        }
        // Live vs cached equivalence through the optimized path.
        let live = job.run(&ExecOptions::new()).unwrap();
        let cached = job.run(&ExecOptions::cached(&cache)).unwrap();
        assert_eq!(cached.sim, live.sim, "{algo:?}");
        assert_eq!(cached.verified, Some(true), "{algo:?}");
    }
}

//! Chaos conformance (the robustness contract): seeded fault injection
//! at the frame layer must be
//!
//! * **invisible** for transient faults — delayed, duplicated and
//!   reordered frames are absorbed by bounded retry, leaving outputs
//!   bit-identical, the delivered report exactly the healthy one, and a
//!   nonzero `retries` counter as the only trace — and
//! * **exactly analyzable** for permanent faults — the mesh's
//!   receive-side [`DegradedReport`](dce::net::DegradedReport) must
//!   equal [`analyze_plan`](dce::net::analyze_plan) on the same spec,
//!   crashed ranks' outputs are dropped, and every untainted survivor
//!   stays bit-identical to the healthy run.
//!
//! Both clauses are pinned across all four A2A variants, both field
//! families, degenerate shapes, and (for a representative shape) all
//! three transports; the coordinator path at the end pins that the
//! repaired coded rows a degraded peer mesh serves match the healthy
//! oracle bit for bit.

use dce::codes::structured::disjoint_family;
use dce::codes::StructuredPoints;
use dce::collectives::{CauchyA2A, DftA2A, DrawLoose, PrepareShoot};
use dce::coordinator::{Engine, ExecOptions, JobConfig, PlanCache};
use dce::framework::{A2aAlgo, SystematicEncode};
use dce::gf::{Field, Gf2e, GfPrime, Mat};
use dce::net::peer::{spawn_local_chaos, RetryPolicy, ShardedPlan};
use dce::net::transport::{ChaosSpec, TransportKind};
use dce::net::{analyze_plan, exec, plan, Collective, FaultSpec, Packet, ProcId};
use dce::util::{ipow, Rng};
use std::sync::Arc;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(20);

/// The transports a conformance sweep runs over.
type Kinds = &'static [TransportKind];

/// The cheap default: variant coverage runs over in-process channels;
/// one representative shape sweeps `TransportKind::ALL` below.
const CH: Kinds = &[TransportKind::Channel];

/// Full-rate transient knobs: every first recv per (round, port, src)
/// times out once, every delivered frame leaves a duplicate behind, and
/// every key is reordered once — deterministic worst-case stacking that
/// stays strictly inside the default retry budget.
fn full_transients(seed: u64) -> ChaosSpec {
    ChaosSpec::new()
        .with_seed(seed)
        .delay(1000, 1)
        .dup(1000)
        .reorder(1000)
}

fn rand_inputs<F: Field>(f: &F, k: usize, w: usize, rng: &mut Rng) -> Vec<Packet> {
    (0..k)
        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
        .collect()
}

/// Compile the collective once, then pin both chaos clauses against the
/// healthy replay oracle: transient-only specs over every `kind` in
/// `kinds`, and a battery of permanent specs (mid-schedule crash,
/// post-run crash, partition, single-round erasure, everything combined
/// with full-rate transients) on the first one.
fn assert_conforms<F, B>(tag: &str, f: &F, p: usize, k: usize, w: usize, kinds: Kinds, build: B)
where
    F: Field + Sync,
    B: FnOnce(Vec<Packet>) -> Box<dyn Collective>,
{
    let compiled = plan::compile(p, k, |basis| Ok(build(basis))).unwrap();
    let mut rng = Rng::new(k as u64 * 6007 + p as u64 * 101 + w as u64);
    let inputs = rand_inputs(f, k, w, &mut rng);
    let rep = exec::replay(&compiled, f, &inputs).unwrap();
    let owners: Vec<ProcId> = (0..compiled.n_inputs).collect();
    let sharded = ShardedPlan::new(&compiled, f, &owners).unwrap();
    let policy = RetryPolicy::default();

    // Clause 1: transient chaos is invisible on every requested
    // transport — bit-identical outputs, healthy delivered report,
    // nothing crashed, nothing tainted, nothing dropped.
    let transient = full_transients(0xC4A0 ^ k as u64);
    for &kind in kinds {
        let run = spawn_local_chaos(&sharded, f, &inputs, kind, TIMEOUT, &transient, &policy)
            .unwrap_or_else(|e| panic!("{tag} over {kind} (transient): {e:#}"));
        assert_eq!(run.outputs, rep.outputs, "{tag} over {kind}: outputs");
        assert_eq!(
            run.report.delivered, rep.report,
            "{tag} over {kind}: transient delivered report"
        );
        assert_eq!(run.report.dropped_messages, 0, "{tag} over {kind}");
        assert!(run.report.crashed.is_empty(), "{tag} over {kind}");
        assert!(run.report.tainted.is_empty(), "{tag} over {kind}");
        assert!(run.crashes_detected.is_empty(), "{tag} over {kind}");
        if rep.report.messages > 0 {
            assert!(
                run.retries > 0 && run.rounds_delayed > 0,
                "{tag} over {kind}: full-rate chaos left no retry trace"
            );
        }
    }

    // Clause 2: permanent specs on the first transport. Every scenario
    // is checked the same way: the peer mesh's report equals the static
    // plan analysis, crashed outputs are gone, survivors bit-identical.
    let kind = kinds[0];
    let check = |what: &str, chaos: &ChaosSpec| {
        let expected = analyze_plan(&compiled, w, &chaos.to_fault_spec());
        let run = spawn_local_chaos(&sharded, f, &inputs, kind, TIMEOUT, chaos, &policy)
            .unwrap_or_else(|e| panic!("{tag} / {what}: {e:#}"));
        assert_eq!(run.report, expected, "{tag} / {what}: peer report");
        for pid in &run.report.crashed {
            let kept = run.outputs.contains_key(pid);
            assert!(!kept, "{tag} / {what}: crashed rank {pid} kept an output");
        }
        for (pid, pkt) in &rep.outputs {
            if run.report.survives(*pid) {
                let got = run.outputs.get(pid);
                assert_eq!(got, Some(pkt), "{tag} / {what}: survivor {pid}");
            }
        }
    };
    let procs = &sharded.procs;
    let mid = procs[procs.len() / 2];
    let mid_round = (sharded.n_rounds as u64 / 2).max(1);
    let mid_crash = ChaosSpec::new().crash_from(mid, mid_round);
    let post_crash = ChaosSpec::new().crash_after(procs[0]);
    check("mid-schedule crash", &mid_crash);
    check("post-run crash", &post_crash);
    if procs.len() > 1 {
        let (a, b) = (procs[0], procs[procs.len() - 1]);
        check("partition", &ChaosSpec::new().partition(a, b));
        check("round-1 erasure", &ChaosSpec::new().erase(1, b, a));
        let combined = full_transients(0xD0C ^ k as u64)
            .crash_from(mid, mid_round)
            .partition(a, b);
        check("combined crash + cut + transients", &combined);
    }
}

#[test]
fn prepare_shoot_prime_including_degenerate() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xCA01);
    for (k, p, w) in [(1usize, 1usize, 1usize), (5, 1, 2), (10, 2, 1)] {
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let tag = format!("ps K={k} p={p} w={w}");
        assert_conforms(&tag, &f, p, k, w, CH, move |ins| {
            Box::new(PrepareShoot::new(f, (0..k).collect(), p, c, ins))
        });
    }
}

#[test]
fn prepare_shoot_gf2e() {
    let f = Gf2e::new(8).unwrap();
    let mut rng = Rng::new(0xCA02);
    let (k, p, w) = (13usize, 2usize, 3usize);
    let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
    let ff = f.clone();
    assert_conforms("ps/gf2e K=13 p=2 w=3", &f, p, k, w, CH, move |ins| {
        Box::new(PrepareShoot::new(ff, (0..k).collect(), p, c, ins))
    });
}

#[test]
fn dft_a2a_both_fields() {
    let f = GfPrime::default_field();
    let (p_base, h, p, w) = (2u64, 3u32, 1usize, 2usize);
    let k = ipow(p_base, h) as usize;
    assert_conforms("dft P=2 H=3 p=1", &f, p, k, w, CH, move |ins| {
        Box::new(DftA2A::new(f, (0..k).collect(), p, p_base, h, ins, false).unwrap())
    });
    // GF(256): q−1 = 255 = 3·5·17 — prime radixes only.
    let f = Gf2e::new(8).unwrap();
    let k = 3usize;
    let ff = f.clone();
    assert_conforms("dft/gf2e P=3 p=2", &f, 2, k, 2, CH, move |ins| {
        Box::new(DftA2A::new(ff, (0..k).collect(), 2, 3, 1, ins, false).unwrap())
    });
}

#[test]
fn draw_loose_both_fields() {
    let f = GfPrime::default_field();
    let (n, p_base, p, w) = (12usize, 2u64, 3usize, 1usize);
    let hmax = StructuredPoints::max_h(&f, n as u64, p_base);
    let m = n / ipow(p_base, hmax) as usize;
    let sp = StructuredPoints::new(&f, n, p_base, (0..m as u64).collect()).unwrap();
    assert_conforms("dl n=12 P=2 p=3", &f, p, n, w, CH, move |ins| {
        Box::new(DrawLoose::new(f, (0..n).collect(), p, &sp, ins, false).unwrap())
    });
    // GF(256), radix 3: M = 2, Z = 3.
    let f = Gf2e::new(8).unwrap();
    let n = 6usize;
    let sp = StructuredPoints::new(&f, n, 3, vec![0, 1]).unwrap();
    let ff = f.clone();
    assert_conforms("dl/gf2e n=6", &f, 1, n, 2, CH, move |ins| {
        Box::new(DrawLoose::new(ff, (0..n).collect(), 1, &sp, ins, false).unwrap())
    });
}

#[test]
fn cauchy_a2a_both_fields() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xCA04);
    let (n, p, w) = (8usize, 1usize, 1usize);
    let fam = disjoint_family(&f, n, 2, 2).unwrap();
    let pre: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
    let post: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
    assert_conforms("cauchy n=8 p=1", &f, p, n, w, CH, move |ins| {
        let a2a = CauchyA2A::new(f, (0..n).collect(), p, &fam[0], &fam[1], pre, post, ins);
        Box::new(a2a.unwrap())
    });
    let f = Gf2e::new(8).unwrap();
    let n = 6usize;
    let fam = disjoint_family(&f, n, 3, 2).unwrap();
    let pre: Vec<u64> = (0..n).map(|_| rng.range(1, 256)).collect();
    let post: Vec<u64> = (0..n).map(|_| rng.range(1, 256)).collect();
    let ff = f.clone();
    assert_conforms("cauchy/gf2e n=6", &f, 1, n, 2, CH, move |ins| {
        let a2a = CauchyA2A::new(ff, (0..n).collect(), 1, &fam[0], &fam[1], pre, post, ins);
        Box::new(a2a.unwrap())
    });
}

#[test]
fn systematic_framework_degenerate_shapes() {
    // The framework around the A2As at the contract's degenerate
    // corners: K=1, R=1, p=1, W=1 (and small mixes).
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xCA05);
    let shapes: [(usize, usize, usize, usize); 3] = [(1, 1, 1, 1), (2, 2, 1, 1), (12, 4, 2, 2)];
    for (k, r, p, w) in shapes {
        let a = Arc::new(Mat::random(&f, k, r, rng.next_u64()));
        let tag = format!("sys K={k} R={r} p={p} w={w}");
        assert_conforms(&tag, &f, p, k, w, CH, move |ins| {
            Box::new(SystematicEncode::new(f, a, ins, p, A2aAlgo::Universal).unwrap())
        });
    }
}

#[test]
fn representative_shape_conforms_on_every_transport() {
    // One mid-sized systematic shape, both chaos clauses, all three
    // substrates — rings and sockets heal exactly like channels.
    let f = GfPrime::default_field();
    let a = Arc::new(Mat::random(&f, 10, 4, 0xCA06));
    assert_conforms("sys K=10 R=4", &f, 2, 10, 2, &TransportKind::ALL, move |ins| {
        Box::new(SystematicEncode::new(f, a, ins, 2, A2aAlgo::Universal).unwrap())
    });
}

#[test]
fn coordinator_recovers_lost_sinks_through_degraded_peer_mesh() {
    // End-to-end healing: a sink crash-stops mid-run and a source dies
    // post-run; on every transport the peer engine's repaired coded
    // rows match the healthy oracle bit for bit, its delivered report
    // matches the replay engine's fault analysis, and the healing
    // telemetry lands in the degraded info.
    let cache = PlanCache::new();
    let cfg = JobConfig {
        k: 12,
        r: 4,
        w: 5,
        ..JobConfig::default()
    };
    let job = dce::coordinator::EncodeJob::synthetic(cfg).unwrap();
    let opts = ExecOptions::cached(&cache);
    let healthy = job
        .encode(&cache, &[job.inputs.as_slice()], &opts)
        .unwrap()
        .coded
        .remove(0);
    let faults = FaultSpec::new().crash_from(13, 2).crash_after(3);
    let replayed = job.run(&opts.faults(&faults)).unwrap();
    let rd = replayed.degraded.as_ref().expect("replay degraded");
    assert_eq!(rd.coded, healthy, "replay oracle sanity");
    for kind in TransportKind::ALL {
        let peer = job
            .run(&opts.faults(&faults).engine(Engine::Peer(kind)))
            .unwrap_or_else(|e| panic!("degraded peer engine over {kind}: {e:#}"));
        let d = peer.degraded.as_ref().expect("peer degraded");
        assert_eq!(d.coded, healthy, "{kind}: repaired rows match");
        assert_eq!(peer.verified, Some(true), "{kind}");
        assert_eq!(peer.sim, replayed.sim, "{kind}: sim reports agree");
        assert_eq!(d.crashed, rd.crashed, "{kind}");
        assert_eq!(d.lost_sinks, rd.lost_sinks, "{kind}");
        assert_eq!(d.surviving_sinks, rd.surviving_sinks, "{kind}");
        assert_eq!(d.outputs_recovered, rd.outputs_recovered, "{kind}");
        // The mid-run sink death is detected on the wire (self-report
        // gossiped); the post-run source death leaves no wire trace.
        assert_eq!(d.peer_crashes_detected, 1, "{kind}");
    }
}

//! Differential suite for the NTT encode backend: the transform
//! pipeline must be **bit-identical** to the dense engine and to live
//! stepping (outputs *and* report) across the GRS/Lagrange × K × B
//! matrix, with non-two-adic fields and non-GRS codes falling back to
//! the dense engine.

use dce::codes::GrsCode;
use dce::framework::{compile_plan, plan, AlgoRequest, CompiledPlan};
use dce::gf::{Field, GfPrime};
use dce::net::{
    replay_batch_kernels, replay_batch_ntt, run, BackendKind, CodeShape, NttBackend, Packet,
    Sim,
};
use dce::util::Rng;

fn sink_rows(c: &CompiledPlan) -> Vec<usize> {
    (0..c.layout.r)
        .map(|r| c.opt.matrix.assignment()[&c.layout.sink(r)])
        .collect()
}

fn shape(code: &GrsCode) -> CodeShape<'_> {
    CodeShape {
        alphas: &code.alphas,
        betas: &code.betas,
        u: &code.u,
        v: &code.v,
    }
}

fn random_jobs(f: &GfPrime, rng: &mut Rng, k: usize, w: usize, b: usize) -> Vec<Vec<Packet>> {
    (0..b)
        .map(|_| {
            (0..k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect()
        })
        .collect()
}

/// The core differential check for one compiled shape: dense engine ≡
/// backend dispatch ≡ (when the shape admits it) the forced NTT path,
/// per job, outputs and report — plus job 0 against a live run.
fn assert_differential(
    f: &GfPrime,
    code: &GrsCode,
    compiled: &CompiledPlan,
    request: AlgoRequest,
    w: usize,
    label: &str,
) {
    let k = code.k();
    let forced = NttBackend::detect(f, &compiled.opt.matrix, &shape(code), &sink_rows(compiled))
        .unwrap();
    let mut rng = Rng::new((k * 31 + w) as u64);
    for b in [1usize, 3, 32] {
        let jobs = random_jobs(f, &mut rng, k, w, b);
        let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();
        let dense = replay_batch_kernels(&compiled.opt, &compiled.kernels, &refs).unwrap();
        let dispatched = compiled.replay_batch(&refs).unwrap();
        assert_eq!(dense.len(), b);
        for j in 0..b {
            assert_eq!(
                dispatched[j].outputs, dense[j].outputs,
                "{label} B={b} job {j}: dispatched vs dense outputs"
            );
            assert_eq!(
                dispatched[j].report, dense[j].report,
                "{label} B={b} job {j}: dispatched vs dense report"
            );
        }
        // Force the transform even below the cost crossover: detection
        // is structural, so tiny K must still be bit-identical.
        if let Some(backend) = &forced {
            let ntt = replay_batch_ntt(&compiled.opt, backend, &refs).unwrap();
            for j in 0..b {
                assert_eq!(
                    ntt[j].outputs, dense[j].outputs,
                    "{label} B={b} job {j}: NTT vs dense outputs"
                );
                assert_eq!(
                    ntt[j].report, dense[j].report,
                    "{label} B={b} job {j}: NTT vs dense report"
                );
            }
        }
        // Live stepping on job 0 (once per shape): same outputs, same
        // report.
        if b == 1 {
            let mut pl = plan(f, Some(code), None, jobs[0].clone(), 1, request).unwrap();
            let live_report = run(&mut Sim::new(1), pl.job.as_mut()).unwrap();
            assert_eq!(
                dense[0].outputs,
                pl.job.outputs(),
                "{label}: dense vs live outputs"
            );
            assert_eq!(dense[0].report, live_report, "{label}: dense vs live report");
        }
    }
}

#[test]
fn ntt_backend_bit_identical_across_grs_and_lagrange_shapes() {
    let f = GfPrime::default_field();
    // (K, R, payload width, expected compile-time backend): the policy
    // serves dense below the op-count crossover, NTT above it.
    for (k, r, w, expect) in [
        (1usize, 1usize, 3usize, BackendKind::Dense),
        (2, 3, 3, BackendKind::Dense),
        (1024, 64, 1, BackendKind::Ntt),
    ] {
        let mut mrng = Rng::new((k + r) as u64);
        let flavors: [(&str, Vec<u64>, Vec<u64>); 2] = [
            ("lagrange", vec![1; k], vec![1; r]),
            (
                "grs",
                (0..k).map(|_| mrng.below(f.order() - 1) + 1).collect(),
                (0..r).map(|_| mrng.below(f.order() - 1) + 1).collect(),
            ),
        ];
        for (flavor, u, v) in flavors {
            let label = format!("{flavor} K={k} R={r}");
            let code = GrsCode::ntt_friendly(&f, k, r, u, v).unwrap();
            let compiled =
                compile_plan(&f, Some(&code), None, 1, w, AlgoRequest::Direct, None).unwrap();
            assert_eq!(compiled.backend.kind(), expect, "{label}: selected backend");
            // The structural detection must succeed on every one of
            // these shapes (the policy gate is what differs).
            let det =
                NttBackend::detect(&f, &compiled.opt.matrix, &shape(&code), &sink_rows(&compiled))
                    .unwrap();
            assert!(det.is_some(), "{label}: NTT shape must be detected");
            // plan_profile records the decision and the op counts
            // behind it.
            let prof = compiled.profile(w as u64);
            assert_eq!(prof.backend, expect, "{label}: profiled backend");
            if expect == BackendKind::Ntt {
                assert!(
                    prof.backend_dense_ops
                        >= dce::net::NTT_DENSE_OP_RATIO * prof.backend_ntt_ops,
                    "{label}: {prof:?} must sit past the crossover"
                );
            }
            assert_differential(&f, &code, &compiled, AlgoRequest::Direct, w, &label);
        }
    }
}

#[test]
fn non_power_of_two_and_non_grs_shapes_fall_back_to_dense() {
    let f = GfPrime::default_field();
    // K = 255: plain sequential points — no root-of-unity geometry.
    let code = GrsCode::plain(&f, (1..=255).collect(), (1000..1016).collect()).unwrap();
    let compiled = compile_plan(&f, Some(&code), None, 1, 1, AlgoRequest::Direct, None).unwrap();
    assert_eq!(compiled.backend.kind(), BackendKind::Dense);
    let det = NttBackend::detect(&f, &compiled.opt.matrix, &shape(&code), &sink_rows(&compiled))
        .unwrap();
    assert!(det.is_none(), "K=255 must not detect as NTT-friendly");
    assert_differential(&f, &code, &compiled, AlgoRequest::Direct, 1, "plain K=255");

    // No code at all (random parity matrix): dense, trivially.
    let parity = std::sync::Arc::new(dce::gf::Mat::random(&f, 8, 4, 7));
    let compiled =
        compile_plan(&f, None, Some(parity), 1, 2, AlgoRequest::Direct, None).unwrap();
    assert_eq!(compiled.backend.kind(), BackendKind::Dense);
}

#[test]
fn non_two_adic_fields_fall_back_to_dense() {
    // GF(2^8): q−1 = 255 is odd — no two-adic root tower, so even a
    // power-of-two K serves dense (and `ntt_friendly` refuses to build).
    let f = dce::gf::Gf2e::new(8).unwrap();
    assert!(GrsCode::ntt_friendly(&f, 8, 4, vec![1; 8], vec![1; 4]).is_err());
    let code = GrsCode::plain(&f, (1..=8).collect(), (20..24).collect()).unwrap();
    let compiled = compile_plan(&f, Some(&code), None, 1, 2, AlgoRequest::Direct, None).unwrap();
    assert_eq!(compiled.backend.kind(), BackendKind::Dense);
}

#[test]
fn rs_ntt_code_kind_serves_through_the_coordinator() {
    use dce::coordinator::{EncodeJob, ExecOptions, JobConfig, PlanCache};
    // The `rs-ntt` config kind builds the NTT-friendly geometry with
    // seeded non-unit multipliers; the cached batch path must verify
    // against the parity oracle whichever backend serves it.
    let cfg_text = "code = \"rs-ntt\"\nk = 16\nr = 4\nw = 3";
    let cfg = JobConfig::parse(cfg_text).unwrap();
    let job = EncodeJob::synthetic(cfg.clone()).unwrap();
    let rep = job.run(&ExecOptions::new()).unwrap();
    assert_eq!(rep.verified, Some(true), "live rs-ntt run verifies");
    let cache = PlanCache::new();
    let f = job.field.clone();
    let mut rng = Rng::new(5);
    let jobs: Vec<Vec<Packet>> = (0..4)
        .map(|_| {
            (0..cfg.k)
                .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                .collect()
        })
        .collect();
    let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();
    let opts = ExecOptions::cached(&cache);
    let batched = job.encode(&cache, &refs, &opts).unwrap().coded;
    for (x, y) in jobs.iter().zip(&batched) {
        assert!(dce::coordinator::verify::native(&f, &job.parity, x, y));
        let one = job.encode(&cache, &[x], &opts).unwrap().coded.remove(0);
        assert_eq!(y, &one);
    }
}

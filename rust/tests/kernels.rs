//! Packed-kernel equivalence suite: every narrow-lane kernel
//! (`gf/kernels.rs`) must agree **bit for bit** with the scalar `u64`
//! `Field` path it replaces —
//!
//! * exhaustively for `GF(2^8)`: all 256 coefficients × unaligned
//!   lengths, for axpy and for full lincombs;
//! * seeded sweeps for every `GF(2^w)` width, the default prime and a
//!   near-`2^31` prime, including term counts straddling the
//!   `lazy_chunk` reduction boundary (≈4 terms for `p = 2^31 − 1`) and
//!   lengths straddling the gemm column tile;
//! * end-to-end: `replay_batch` (packed) ≡ `replay_batch_scalar` ≡
//!   per-job `replay` through a compiled plan, both field families.
//!
//! Every sweep runs once per **executable ISA tier**
//! ([`IsaTier::available`]) so the explicit-SIMD backends are pinned to
//! the scalar packed engine on whatever host runs the suite; CI's
//! forced-tier matrix (`DCE_FORCE_ISA`) re-runs the whole suite per
//! tier on top.

use dce::gf::matrix::{gemm_into, GEMM_TILE};
use dce::gf::{AnyField, Field, Gf2e, GfPrime, IsaTier, Kernels, SymbolLayout};
use dce::net::{exec, plan, Packet};
use dce::util::Rng;

/// Unaligned lengths: primes/odd sizes around cache-line and vector
/// register widths — including one-below (31) and far-above (512) the
/// 32-lane AVX2 step — so no kernel gets to rely on alignment and every
/// SIMD main loop exercises both its vector body and its scalar tail.
const LENGTHS: [usize; 9] = [1, 3, 7, 15, 31, 33, 100, 257, 512];

/// The tiers this host can execute, scalar first. Tiers are selected
/// through the API (`Kernels::for_field_with_isa`), **not** the
/// `DCE_FORCE_ISA` env var: the test harness runs tests on parallel
/// threads and the env override is latched process-wide on first
/// detection, so per-test env mutation would race. CI exercises the env
/// path via its forced-tier matrix instead.
fn tiers() -> Vec<IsaTier> {
    IsaTier::available()
}

fn rand_vec<F: Field>(f: &F, n: usize, rng: &mut Rng) -> Vec<u64> {
    (0..n).map(|_| rng.below(f.order())).collect()
}

/// Scalar-oracle lincomb: the `Field` trait path over `u64`s.
fn scalar_lincomb<F: Field>(f: &F, init: &[u64], coeffs: &[u64], srcs: &[Vec<u64>]) -> Vec<u64> {
    let mut acc = init.to_vec();
    let terms: Vec<(u64, &[u64])> = coeffs
        .iter()
        .zip(srcs)
        .map(|(&c, s)| (c, s.as_slice()))
        .collect();
    f.lincomb_into(&mut acc, &terms);
    acc
}

/// Packed lincomb through the vtable, unpacked back to `u64`.
fn packed_lincomb(kern: &Kernels, init: &[u64], coeffs: &[u64], srcs: &[Vec<u64>]) -> Vec<u64> {
    let mut acc = kern.pack(init);
    let flat: Vec<u64> = srcs.iter().flatten().copied().collect();
    kern.lincomb(&mut acc, coeffs, &kern.pack(&flat)).unwrap();
    acc.to_u64()
}

#[test]
fn gf256_axpy_exhaustive_over_all_coefficients() {
    let f = Gf2e::new(8).unwrap();
    for tier in tiers() {
        let kern = Kernels::for_field_with_isa(&f, tier);
        assert_eq!(kern.layout(), SymbolLayout::U8);
        assert_eq!(kern.isa(), tier);
        let mut rng = Rng::new(0x256);
        for n in LENGTHS {
            // Sources seeded with zeros interleaved — the zero-symbol
            // skip of the log path has no analogue in the table path,
            // and both must still agree.
            let mut src = rand_vec(&f, n, &mut rng);
            if n > 2 {
                src[n / 2] = 0;
                src[n - 1] = 0;
            }
            let acc0 = rand_vec(&f, n, &mut rng);
            for c in 0..256u64 {
                let mut scalar = acc0.clone();
                f.axpy_into(&mut scalar, c, &src);
                let mut packed = kern.pack(&acc0);
                kern.axpy(&mut packed, c, &kern.pack(&src)).unwrap();
                assert_eq!(packed.to_u64(), scalar, "{tier:?} c={c} n={n}");
            }
        }
    }
}

#[test]
fn gf256_lincomb_exhaustive_coefficient_sweep() {
    // Every coefficient appears in some lincomb: 32 lincombs of 8 terms
    // cover 0..256 exactly, on an unaligned length.
    let f = Gf2e::new(8).unwrap();
    for tier in tiers() {
        let kern = Kernels::for_field_with_isa(&f, tier);
        let mut rng = Rng::new(0x257);
        let n = 37;
        for block in 0..32u64 {
            let coeffs: Vec<u64> = (0..8).map(|i| block * 8 + i).collect();
            let srcs: Vec<Vec<u64>> = (0..8).map(|_| rand_vec(&f, n, &mut rng)).collect();
            let init = rand_vec(&f, n, &mut rng);
            assert_eq!(
                packed_lincomb(&kern, &init, &coeffs, &srcs),
                scalar_lincomb(&f, &init, &coeffs, &srcs),
                "{tier:?} coefficient block {block}"
            );
        }
    }
}

#[test]
fn gf2e_every_width_seeded_sweep() {
    let mut rng = Rng::new(0x2E);
    for w in 1..=16u32 {
        let f = Gf2e::new(w).unwrap();
        for tier in tiers() {
            let kern = Kernels::for_field_with_isa(&f, tier);
            assert_eq!(
                kern.layout(),
                if w <= 8 { SymbolLayout::U8 } else { SymbolLayout::U16 },
                "w={w}"
            );
            // 35 straddles both the 16-lane wide-gather step and the
            // 32-lane nibble step, leaving a ragged scalar tail.
            for n in [1usize, 9, 35, 64] {
                let n_terms = 5;
                let coeffs = rand_vec(&f, n_terms, &mut rng);
                let srcs: Vec<Vec<u64>> =
                    (0..n_terms).map(|_| rand_vec(&f, n, &mut rng)).collect();
                let init = rand_vec(&f, n, &mut rng);
                assert_eq!(
                    packed_lincomb(&kern, &init, &coeffs, &srcs),
                    scalar_lincomb(&f, &init, &coeffs, &srcs),
                    "{tier:?} w={w} n={n}"
                );
            }
        }
    }
}

#[test]
fn prime_fields_across_lazy_chunk_boundaries() {
    // The near-2^31 prime reduces every ~4 terms; the default prime
    // every ~3·10^7 (i.e. once). Sweep term counts straddling both
    // boundaries plus the plain small fields.
    let mut rng = Rng::new(0x31);
    for p in [786433u64, 2147483647, 65537, 257, 251] {
        let f = GfPrime::new(p).unwrap();
        for tier in tiers() {
            let kern = Kernels::for_field_with_isa(&f, tier);
            assert_eq!(kern.layout(), SymbolLayout::for_bits(f.bits()), "p={p}");
            let chunk = f.lazy_chunk();
            let mut term_counts = vec![1usize, 2, 3, 4, 5, 8, 9, 17, 100];
            for d in [-1i64, 0, 1] {
                let t = chunk as i64 + d;
                if (1..=256).contains(&t) {
                    term_counts.push(t as usize);
                }
            }
            for &n_terms in &term_counts {
                // 5 leaves a pure scalar tail on the 4-wide fma lanes;
                // 37 exercises vector body + tail.
                for n in [1usize, 5, 37] {
                    let coeffs = rand_vec(&f, n_terms, &mut rng);
                    let srcs: Vec<Vec<u64>> =
                        (0..n_terms).map(|_| rand_vec(&f, n, &mut rng)).collect();
                    let init = rand_vec(&f, n, &mut rng);
                    assert_eq!(
                        packed_lincomb(&kern, &init, &coeffs, &srcs),
                        scalar_lincomb(&f, &init, &coeffs, &srcs),
                        "{tier:?} p={p} terms={n_terms} n={n}"
                    );
                }
            }
            // Worst-case coefficients/symbols (p−1 everywhere) right at
            // the chunk boundary — the overflow-headroom edge.
            let n_terms = chunk.min(64);
            let coeffs = vec![p - 1; n_terms];
            let srcs: Vec<Vec<u64>> = (0..n_terms).map(|_| vec![p - 1; 8]).collect();
            let init = vec![p - 1; 8];
            assert_eq!(
                packed_lincomb(&kern, &init, &coeffs, &srcs),
                scalar_lincomb(&f, &init, &coeffs, &srcs),
                "{tier:?} p={p} worst-case chunk"
            );
        }
    }
}

#[test]
fn packed_gemm_matches_scalar_gemm_across_tile_seam() {
    let mut rng = Rng::new(0x93);
    for spec in ["gf2e:8", "gf2e:12", "786433", "2147483647"] {
        let f = AnyField::parse(spec).unwrap();
        for tier in tiers() {
            let kern = Kernels::for_field_with_isa(&f, tier);
            for (m, k, n) in [(3usize, 5usize, 33usize), (4, 7, GEMM_TILE + 29)] {
                let mut a: Vec<u64> = rand_vec(&f, m * k, &mut rng);
                a[1] = 0; // zero-coefficient skip must not change results
                let b: Vec<u64> = rand_vec(&f, k * n, &mut rng);
                let mut scalar = vec![0u64; m * n];
                gemm_into(&f, m, k, &a, &b, n, &mut scalar);
                let rows: Vec<&[u64]> = (0..m).map(|i| &a[i * k..(i + 1) * k]).collect();
                let mut packed = kern.zeros(m * n);
                kern.gemm_rows(&rows, &kern.pack(&b), n, &mut packed, false)
                    .unwrap();
                assert_eq!(packed.to_u64(), scalar, "{tier:?} {spec} m={m} k={k} n={n}");
            }
        }
    }
}

#[test]
fn packed_replay_batch_equals_scalar_and_raw_replay() {
    use dce::collectives::PrepareShoot;
    use dce::gf::Mat;
    use std::sync::Arc;
    let mut rng = Rng::new(0xE2E);
    for spec in ["786433", "gf2e:8"] {
        let f = AnyField::parse(spec).unwrap();
        let (k, ports) = (12usize, 2usize);
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let ff = f.clone();
        let c2 = c.clone();
        let compiled = plan::compile(ports, k, move |basis| {
            Ok(Box::new(PrepareShoot::new(
                ff.clone(),
                (0..k).collect(),
                ports,
                c2.clone(),
                basis,
            )))
        })
        .unwrap();
        let opt = dce::net::optimize(&compiled);
        for (b, w) in [(1usize, 3usize), (5, 1), (32, 4)] {
            let jobs: Vec<Vec<Packet>> = (0..b)
                .map(|_| (0..k).map(|_| rand_vec(&f, w, &mut rng)).collect())
                .collect();
            let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();
            let packed = exec::replay_batch(&opt, &f, &refs).unwrap();
            let scalar = exec::replay_batch_scalar(&opt, &f, &refs).unwrap();
            for j in 0..b {
                let raw = exec::replay(&compiled, &f, &jobs[j]).unwrap();
                assert_eq!(packed[j].outputs, raw.outputs, "{spec} B={b} job {j}");
                assert_eq!(scalar[j].outputs, raw.outputs, "{spec} B={b} job {j} scalar");
                assert_eq!(packed[j].report, raw.report, "{spec} B={b} job {j} report");
            }
            for tier in tiers() {
                let kern = Kernels::for_field_with_isa(&f, tier);
                let pre = exec::replay_batch_kernels(&opt, &kern, &refs).unwrap();
                for j in 0..b {
                    assert_eq!(
                        pre[j].outputs, scalar[j].outputs,
                        "{tier:?} {spec} B={b} job {j} kernels"
                    );
                    assert_eq!(
                        pre[j].report, scalar[j].report,
                        "{tier:?} {spec} B={b} job {j} kernels report"
                    );
                }
            }
        }
    }
}

//! Paper-conformance suite: wherever the paper's preconditions hold, the
//! engine-measured `C1`/`C2` must **exactly equal** the closed-form
//! expressions of `framework::costs` (Theorems 1–9, Lemmas 1–4,
//! Corollary 1) — not just respect the lower bounds.
//!
//! Also the engine-equivalence acceptance test: a prepare-and-shoot run
//! at N = 1024, p = 4, W = 64 completes and is bit-identical under the
//! sequential and (when compiled) rayon-parallel round steps.

use dce::codes::{structured::disjoint_family, StructuredPoints};
use dce::collectives::{CauchyA2A, DftA2A, DrawLoose, PrepareShoot};
use dce::framework::{costs, A2aAlgo, SystematicEncode};
use dce::gf::{Field, GfPrime, Mat};
use dce::net::{run, Collective, Packet, Sim};
use dce::util::ipow;
use std::sync::Arc;

fn f() -> GfPrime {
    GfPrime::default_field()
}

fn inputs(k: usize, w: usize, salt: u64) -> Vec<Packet> {
    let f = f();
    (0..k)
        .map(|i| {
            (0..w)
                .map(|j| f.elem((i * w + j) as u64 * 2654435761 + salt))
                .collect()
        })
        .collect()
}

/// Serialises the tests that toggle the global parallel/sequential mode.
static MODE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[test]
fn prepare_shoot_equals_theorem3_on_exact_powers() {
    let f = f();
    for p in [1usize, 2, 3] {
        let mut k = p + 1;
        while k <= 256 {
            for w in [1usize, 3] {
                let c = Arc::new(Mat::random(&f, k, k, (k * 7 + p) as u64));
                let mut ps = PrepareShoot::new(f, (0..k).collect(), p, c, inputs(k, w, 11));
                let rep = run(&mut Sim::new(p), &mut ps).unwrap();
                let (c1, c2) = costs::theorem3_universal(k as u64, p as u64);
                assert_eq!(rep.c1, c1, "C1: K={k} p={p}");
                assert_eq!(rep.c2, w as u64 * c2, "C2: K={k} p={p} w={w}");
                // The phase split matches Lemmas 3–4 exactly.
                let (c1p, c2p) = costs::lemma3_prepare(k as u64, p as u64);
                let (c1s, c2s) = costs::lemma4_shoot(k as u64, p as u64);
                assert_eq!(c1, c1p + c1s, "K={k} p={p}");
                assert_eq!(c2, c2p + c2s, "K={k} p={p}");
                // And C1 is the Lemma-1 optimum.
                assert_eq!(c1, costs::lemma1_c1_lower_bound(k as u64, p as u64));
            }
            k *= p + 1;
        }
    }
}

#[test]
fn dft_equals_theorem4_when_radix_is_power_of_ports_plus_1() {
    let f = f();
    // P = (p+1)^ℓ makes the per-step P×P universal A2A measured-exact,
    // so Theorem 4's H·C_univ(P) holds with equality.
    for (p_base, h, p) in [
        (2u64, 3u32, 1usize),
        (2, 6, 1),
        (4, 2, 1),
        (4, 3, 3),
        (8, 2, 1),
        (16, 2, 3),
    ] {
        let k = ipow(p_base, h) as usize;
        for w in [1usize, 2] {
            let mut d =
                DftA2A::new(f, (0..k).collect(), p, p_base, h, inputs(k, w, 3), false).unwrap();
            let rep = run(&mut Sim::new(p), &mut d).unwrap();
            let (c1, c2) = costs::theorem4_dft(p_base, h, p as u64);
            assert_eq!(rep.c1, c1, "C1: P={p_base} H={h} p={p}");
            assert_eq!(rep.c2, w as u64 * c2, "C2: P={p_base} H={h} p={p} w={w}");
            // Corollary 1 is the P = p+1 diagonal.
            if p_base == p as u64 + 1 {
                assert_eq!((c1, c2), costs::corollary1_dft(h));
            }
        }
    }
}

#[test]
fn draw_loose_equals_theorem5() {
    let f = f();
    // (M, P, H) with M and P powers of p+1 = 2 — both cost components
    // measured-exact.
    for (m, h) in [(1usize, 4u32), (2, 3), (4, 2), (4, 4)] {
        let n = m * ipow(2, h) as usize;
        let sp = StructuredPoints::with_h(&f, n, 2, h, (0..m as u64).collect()).unwrap();
        let mut dl = DrawLoose::new(f, (0..n).collect(), 1, &sp, inputs(n, 1, 9), false).unwrap();
        let rep = run(&mut Sim::new(1), &mut dl).unwrap();
        let (c1, c2) = costs::theorem5_vandermonde(m as u64, 2, h, 1);
        assert_eq!((rep.c1, rep.c2), (c1, c2), "M={m} H={h}");
        // Lemma 6: the inverse costs the same.
        let mut inv = DrawLoose::new(f, (0..n).collect(), 1, &sp, inputs(n, 1, 10), true).unwrap();
        let rep_inv = run(&mut Sim::new(1), &mut inv).unwrap();
        assert_eq!((rep_inv.c1, rep_inv.c2), (c1, c2), "inverse M={m} H={h}");
    }
}

#[test]
fn cauchy_equals_theorem7() {
    let f = f();
    for n in [8usize, 16, 32] {
        let fam = disjoint_family(&f, n, 2, 2).unwrap();
        let (spa, spb) = (&fam[0], &fam[1]);
        assert!(
            spa.m.is_power_of_two(),
            "shape chosen so M is a power of p+1"
        );
        let pre: Vec<u64> = (0..n as u64).map(|i| f.elem(i * 3 + 1)).collect();
        let post: Vec<u64> = (0..n as u64).map(|i| f.elem(i * 5 + 2)).collect();
        let mut ca = CauchyA2A::new(
            f,
            (0..n).collect(),
            1,
            spa,
            spb,
            pre,
            post,
            inputs(n, 1, 4),
        )
        .unwrap();
        let rep = run(&mut Sim::new(1), &mut ca).unwrap();
        let (c1, c2) = costs::theorem7_cauchy(spa.m as u64, spa.p_base, spa.h, 1);
        assert_eq!((rep.c1, rep.c2), (c1, c2), "n={n}");
    }
}

#[test]
fn frameworks_compose_per_theorems_1_and_2() {
    let f = f();
    // K ≥ R (Theorem 1): R = (p+1)^ℓ makes the block A2A measured-exact;
    // the reduce tree over M+1 grid nodes is always exact (Appendix A).
    for (k, r, p, w) in [
        (16usize, 4usize, 1usize, 1usize),
        (16, 4, 1, 5),
        (64, 16, 1, 1),
        (25, 4, 1, 1),
        (81, 9, 2, 2),
    ] {
        let a = Arc::new(Mat::random(&f, k, r, (k * 100 + r) as u64));
        let mut job = SystematicEncode::new(f, a, inputs(k, w, 8), p, A2aAlgo::Universal).unwrap();
        let rep = run(&mut Sim::new(p), &mut job).unwrap();
        let a2a = costs::theorem3_universal(r as u64, p as u64);
        let a2a = (a2a.0, a2a.1 * w as u64);
        let (c1, c2) = costs::theorem1_framework(a2a, k as u64, r as u64, w as u64, p as u64);
        assert_eq!((rep.c1, rep.c2), (c1, c2), "K={k} R={r} p={p} w={w}");
    }
    // K < R (Theorem 2): K = (p+1)^ℓ.
    for (k, r, p, w) in [
        (4usize, 16usize, 1usize, 1usize),
        (4, 25, 1, 1),
        (16, 64, 1, 3),
        (9, 81, 2, 1),
    ] {
        let a = Arc::new(Mat::random(&f, k, r, (k * 100 + r) as u64));
        let mut job = SystematicEncode::new(f, a, inputs(k, w, 8), p, A2aAlgo::Universal).unwrap();
        let rep = run(&mut Sim::new(p), &mut job).unwrap();
        let a2a = costs::theorem3_universal(k as u64, p as u64);
        let a2a = (a2a.0, a2a.1 * w as u64);
        let (c1, c2) = costs::theorem2_framework(a2a, k as u64, r as u64, w as u64, p as u64);
        assert_eq!((rep.c1, rep.c2), (c1, c2), "K={k} R={r} p={p} w={w}");
    }
}

/// Run a collective twice — parallel round steps off, then on — and
/// require bit-identical reports, traces and outputs. Without the
/// `parallel` feature both runs are sequential and this degenerates to a
/// determinism check.
fn assert_mode_identical(p: usize, build: &dyn Fn() -> Box<dyn Collective>) {
    let _guard = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let go = |on: bool| {
        dce::net::set_parallel(on);
        let mut c = build();
        let mut sim = Sim::with_trace(p);
        let rep = run(&mut sim, c.as_mut()).unwrap();
        dce::net::set_parallel(true);
        (rep, sim.trace, c.outputs())
    };
    let (rep_seq, trace_seq, out_seq) = go(false);
    let (rep_par, trace_par, out_par) = go(true);
    assert_eq!(rep_seq, rep_par, "report differs between modes");
    assert_eq!(trace_seq, trace_par, "trace differs between modes");
    assert_eq!(out_seq, out_par, "outputs differ between modes");
}

#[test]
fn parallel_bit_identity_across_collective_families() {
    let f = f();
    // Prepare-and-shoot with the eq. (4) correction path (K = 65, p = 2).
    let c = Arc::new(Mat::random(&f, 65, 65, 65));
    let ins = inputs(65, 3, 1);
    assert_mode_identical(2, &move || {
        let b: Box<dyn Collective> = Box::new(PrepareShoot::new(
            f,
            (0..65).collect(),
            2,
            c.clone(),
            ins.clone(),
        ));
        b
    });
    // DFT (Par of groups inside a Pipeline).
    let ins = inputs(16, 2, 2);
    assert_mode_identical(1, &move || {
        let b: Box<dyn Collective> =
            Box::new(DftA2A::new(f, (0..16).collect(), 1, 2, 4, ins.clone(), false).unwrap());
        b
    });
    // Full framework (broadcast + Par + reduce phases).
    let a = Arc::new(Mat::random(&f, 25, 4, 12));
    let ins = inputs(25, 2, 3);
    assert_mode_identical(1, &move || {
        let b: Box<dyn Collective> = Box::new(
            SystematicEncode::new(f, a.clone(), ins.clone(), 1, A2aAlgo::Universal).unwrap(),
        );
        b
    });
}

/// Acceptance: a full prepare-and-shoot at N = 1024, p = 4, W = 64
/// completes, parallel and sequential engines agree bit-for-bit, C1 is
/// the Lemma-1 optimum and C2 respects Theorem 3.
#[test]
fn n1024_p4_w64_parallel_matches_sequential() {
    let _guard = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let f = f();
    let (k, p, w) = (1024usize, 4usize, 64usize);
    let c = Arc::new(Mat::random(&f, k, k, 0xBEEF));
    let ins = inputs(k, w, 77);
    let go = |on: bool| {
        dce::net::set_parallel(on);
        let mut ps = PrepareShoot::new(f, (0..k).collect(), p, c.clone(), ins.clone());
        let mut sim = Sim::with_trace(p);
        let rep = run(&mut sim, &mut ps).unwrap();
        dce::net::set_parallel(true);
        (rep, sim.trace, ps.outputs())
    };
    let (rep_seq, trace_seq, out_seq) = go(false);
    let (rep_par, trace_par, out_par) = go(true);
    assert_eq!(rep_seq, rep_par, "C1/C2 must be engine-independent");
    assert_eq!(trace_seq, trace_par);
    assert_eq!(out_seq, out_par);
    assert_eq!(
        rep_seq.c1,
        costs::lemma1_c1_lower_bound(k as u64, p as u64)
    );
    let (_, c2_bound) = costs::theorem3_universal(k as u64, p as u64);
    assert!(rep_seq.c2 <= w as u64 * c2_bound);
    assert!(rep_seq.c2 as f64 >= costs::lemma2_c2_lower_bound(k as u64, p as u64) * w as f64);
}

//! Fault-scenario conformance: for every planner algorithm, over both
//! field families, across degenerate shapes and **every failure count
//! from 0 to R**, recovery from crashed processors must reproduce all
//! sink outputs **bit-identically** to the healthy run — through both
//! the live-sim (`Engine::Live` + `ExecOptions::faults`) and the
//! batched-replay (`Engine::Replay` / `net::exec::replay_degraded_batch`)
//! paths.
//!
//! Also asserts the two engines produce identical failure analyses
//! (delivered traffic, crashed/tainted sets, lost sinks) for mid-run
//! crash-stop, dropped-link and per-round-erasure scenarios, and that
//! unrecoverable patterns (fewer than `K` surviving coordinates) fail
//! with a typed [`dce::Error::Unrecoverable`] on both paths instead of
//! fabricating data.

use dce::coordinator::{
    config::CodeKind, DegradedInfo, EncodeJob, ExecOptions, JobConfig, JobReport, PlanCache,
};
use dce::framework::AlgoRequest;
use dce::net::{FaultSpec, POST_RUN};

fn job_for(
    field: &str,
    algo: AlgoRequest,
    code: CodeKind,
    k: usize,
    r: usize,
    ports: usize,
    w: usize,
) -> EncodeJob {
    let cfg = JobConfig {
        field: field.into(),
        k,
        r,
        w,
        ports,
        code,
        algorithm: algo,
        seed: (k * 1000 + r * 10 + ports) as u64,
        ..JobConfig::default()
    };
    EncodeJob::synthetic(cfg).unwrap()
}

fn healthy_rows(job: &EncodeJob, cache: &PlanCache) -> Vec<Vec<u64>> {
    job.encode(cache, &[&job.inputs], &ExecOptions::cached(cache))
        .unwrap()
        .coded
        .remove(0)
}

/// Run both degraded paths under `faults` and assert full bit-identical
/// repair against the healthy coded rows. Returns the live report plus
/// its degraded analysis.
fn assert_recovers(
    tag: &str,
    job: &EncodeJob,
    cache: &PlanCache,
    healthy: &[Vec<u64>],
    faults: &FaultSpec,
) -> (JobReport, DegradedInfo) {
    let live = job
        .run(&ExecOptions::new().faults(faults))
        .unwrap_or_else(|e| {
            panic!("{tag}: live degraded run failed: {e:#}");
        });
    let ld = live
        .degraded
        .clone()
        .expect("fault-injected run reports degraded info");
    assert_eq!(ld.coded, healthy, "{tag}: live repair ≡ healthy");
    assert_eq!(live.verified, Some(true), "{tag}: live verification");
    assert_eq!(
        ld.outputs_recovered,
        ld.lost_sinks.len(),
        "{tag}: every lost sink recovered"
    );
    let cached = job
        .run(&ExecOptions::cached(cache).faults(faults))
        .unwrap_or_else(|e| {
            panic!("{tag}: cached degraded run failed: {e:#}");
        });
    let cd = cached
        .degraded
        .expect("fault-injected replay reports degraded info");
    assert_eq!(cd.coded, healthy, "{tag}: cached repair ≡ healthy");
    assert_eq!(cached.sim, live.sim, "{tag}: delivered stats live ≡ replay");
    assert_eq!(cd.crashed, ld.crashed, "{tag}: crashed sets");
    assert_eq!(cd.lost_sinks, ld.lost_sinks, "{tag}: lost sinks");
    assert_eq!(
        cd.surviving_sinks, ld.surviving_sinks,
        "{tag}: surviving sinks"
    );
    (live, ld)
}

/// The satellite grid: every planner algorithm × both fields, post-run
/// losses of every size 0..=R drawn over sources *and* sinks.
#[test]
fn every_algorithm_and_field_recovers_from_any_post_run_loss() {
    let grid: &[(&str, AlgoRequest, CodeKind, usize, usize, usize, usize)] = &[
        // prime field (q = 786433)
        ("prime:786433", AlgoRequest::RsSpecific, CodeKind::RsStructured, 16, 4, 2, 3),
        ("prime:786433", AlgoRequest::RsSpecific, CodeKind::RsStructured, 4, 8, 1, 2),
        ("prime:786433", AlgoRequest::Universal, CodeKind::RsPlain, 12, 5, 2, 4),
        ("prime:786433", AlgoRequest::MultiReduce, CodeKind::Lagrange, 6, 3, 1, 2),
        ("prime:786433", AlgoRequest::Direct, CodeKind::RsStructured, 8, 4, 2, 1),
        // GF(2^8) (q − 1 = 255 — structured codes pick radix 3)
        ("gf2e:8", AlgoRequest::RsSpecific, CodeKind::RsStructured, 6, 3, 1, 3),
        ("gf2e:8", AlgoRequest::Universal, CodeKind::RsPlain, 7, 4, 2, 2),
        ("gf2e:8", AlgoRequest::MultiReduce, CodeKind::RsPlain, 5, 2, 1, 1),
        ("gf2e:8", AlgoRequest::Direct, CodeKind::Lagrange, 4, 4, 1, 2),
    ];
    for &(field, algo, code, k, r, p, w) in grid {
        let tag = format!("{field} {algo:?} K={k} R={r}");
        let job = job_for(field, algo, code, k, r, p, w);
        let cache = PlanCache::new();
        let healthy = healthy_rows(&job, &cache);
        let procs: Vec<usize> = (0..k + r).collect();
        for failures in 0..=r {
            let faults =
                FaultSpec::random_crashes(failures as u64 * 31 + 7, &procs, failures, POST_RUN);
            let (_, info) = assert_recovers(
                &format!("{tag} failures={failures}"),
                &job,
                &cache,
                &healthy,
                &faults,
            );
            assert_eq!(info.faults_injected, failures as u64);
            assert_eq!(info.crashed.len(), failures);
        }
    }
}

/// The degenerate corners the satellite names: K=1, R=1, p=1, W=1 (and
/// small mixes), every algorithm, every failure count.
#[test]
fn degenerate_shapes_recover_for_every_algorithm() {
    for algo in [
        AlgoRequest::Auto,
        AlgoRequest::Universal,
        AlgoRequest::MultiReduce,
        AlgoRequest::Direct,
        AlgoRequest::RsSpecific,
    ] {
        for (k, r, p, w) in [
            (1usize, 1usize, 1usize, 1usize),
            (2, 1, 1, 1),
            (1, 2, 1, 1),
            (1, 1, 1, 3),
        ] {
            let tag = format!("{algo:?} K={k} R={r} p={p} W={w}");
            let job = job_for("prime:786433", algo, CodeKind::RsStructured, k, r, p, w);
            let cache = PlanCache::new();
            let healthy = healthy_rows(&job, &cache);
            let procs: Vec<usize> = (0..k + r).collect();
            for failures in 0..=r {
                let faults = FaultSpec::random_crashes(
                    failures as u64 + 1,
                    &procs,
                    failures,
                    POST_RUN,
                );
                assert_recovers(
                    &format!("{tag} failures={failures}"),
                    &job,
                    &cache,
                    &healthy,
                    &faults,
                );
            }
        }
    }
}

/// Mid-encode crash of a reduce-root sink: in the divisible K ≥ R
/// framework a sink only *receives* (phase-2 reduce root), so killing it
/// from round 1 loses exactly its own output — recoverable from the
/// other N−1 coordinates even though messages were really dropped
/// mid-protocol.
#[test]
fn mid_encode_sink_crash_loses_only_that_sink() {
    let job = job_for("prime:786433", AlgoRequest::Universal, CodeKind::RsStructured, 16, 4, 1, 2);
    let cache = PlanCache::new();
    let healthy = healthy_rows(&job, &cache);
    for sink in 0..4usize {
        let faults = FaultSpec::new().crash(16 + sink);
        let (rep, info) = assert_recovers(
            &format!("sink {sink} dead from round 1"),
            &job,
            &cache,
            &healthy,
            &faults,
        );
        assert_eq!(info.lost_sinks, vec![sink]);
        assert!(rep.sim.messages > 0, "the rest of the protocol ran");
    }
    // Same story through a dropped last-hop link: source 0 is the rank-1
    // child of row 0's reduce, so killing link 0 → sink 16 taints only
    // the sink.
    let faults = FaultSpec::new().drop_link(0, 16);
    let (_, info) = assert_recovers("link 0→16 dropped", &job, &cache, &healthy, &faults);
    assert_eq!(info.lost_sinks, vec![0]);
    assert!(info.crashed.is_empty(), "nobody crashed — taint only");
}

/// Mid-encode *source* crashes: taint may spread to every sink, in
/// which case fewer than K coordinates survive and both paths must
/// refuse identically (a typed `Error::Unrecoverable`, never fabricated
/// data); when enough coordinates survive, both paths must repair
/// identically.
#[test]
fn mid_encode_source_crash_is_consistent_across_engines() {
    for algo in [
        AlgoRequest::Universal,
        AlgoRequest::MultiReduce,
        AlgoRequest::Direct,
        AlgoRequest::RsSpecific,
    ] {
        let job = job_for("prime:786433", algo, CodeKind::RsStructured, 16, 4, 1, 2);
        let cache = PlanCache::new();
        let healthy = healthy_rows(&job, &cache);
        for spec in [
            FaultSpec::new().crash_from(3, 2),
            FaultSpec::new().erase(1, 1, 2),
            FaultSpec::new().crash_from(0, 3).crash_after(17),
        ] {
            let tag = format!("{algo:?} {spec:?}");
            let live = job.run(&ExecOptions::new().faults(&spec));
            let cached = job.run(&ExecOptions::cached(&cache).faults(&spec));
            match (live, cached) {
                (Ok(l), Ok(c)) => {
                    let ld = l.degraded.expect("degraded info");
                    let cd = c.degraded.expect("degraded info");
                    assert_eq!(ld.coded, healthy, "{tag}: live repair");
                    assert_eq!(cd.coded, healthy, "{tag}: cached repair");
                    assert_eq!(l.sim, c.sim, "{tag}: delivered stats");
                    assert_eq!(ld.lost_sinks, cd.lost_sinks, "{tag}: lost sinks");
                }
                (Err(le), Err(ce)) => {
                    assert!(
                        matches!(le, dce::Error::Unrecoverable(_)),
                        "{tag}: live error not typed: {le:#?}"
                    );
                    assert!(
                        le.to_string().contains("unrecoverable"),
                        "{tag}: live error: {le:#}"
                    );
                    assert!(
                        ce.to_string().contains("unrecoverable"),
                        "{tag}: cached error: {ce:#}"
                    );
                }
                (l, c) => panic!(
                    "{tag}: engines disagree — live {:?}, cached {:?}",
                    l.map(|r| r.degraded.map(|d| d.lost_sinks)),
                    c.map(|r| r.degraded.map(|d| d.lost_sinks))
                ),
            }
        }
    }
}

/// The degraded batch path serves B jobs through one analysis + one
/// columnar pass, bit-identical per job to the healthy batch.
#[test]
fn degraded_batch_is_bit_identical_per_job_across_widths() {
    use dce::gf::Field;
    let job = job_for("prime:786433", AlgoRequest::Universal, CodeKind::RsStructured, 8, 4, 2, 4);
    let cache = PlanCache::new();
    let f = job.field.clone();
    let mut rng = dce::util::Rng::new(99);
    let procs: Vec<usize> = (0..12).collect();
    let faults = FaultSpec::random_crashes(5, &procs, 4, POST_RUN);
    for (b, w) in [(1usize, 1usize), (3, 5), (16, 2)] {
        let jobs: Vec<Vec<Vec<u64>>> = (0..b)
            .map(|_| {
                (0..8)
                    .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                    .collect()
            })
            .collect();
        let refs: Vec<&[Vec<u64>]> = jobs.iter().map(|x| x.as_slice()).collect();
        let base = ExecOptions::cached(&cache);
        let healthy = job.encode(&cache, &refs, &base).unwrap();
        assert!(healthy.recovery.is_none(), "healthy batch reports no recovery");
        let degraded = job.encode(&cache, &refs, &base.faults(&faults)).unwrap();
        assert_eq!(degraded.coded, healthy.coded, "B={b} W={w}");
        let stats = degraded.recovery.expect("fault-injected batch reports stats");
        assert_eq!(stats.outputs_recovered, (stats.outputs_lost * b) as u64);
    }
}

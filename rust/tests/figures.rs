//! The paper's worked examples (Figures 2–9), asserted against the
//! engine's traces and cost reports.

use dce::collectives::{DftA2A, PrepareShoot};
use dce::framework::{A2aAlgo, NonSystematicEncode, SystematicEncode};
use dce::gf::{dft, Field, GfPrime, Mat};
use dce::net::{pkt_add_scaled, pkt_zero, run, trace, Collective, Packet, Sim};
use dce::util::ipow;
use std::sync::Arc;

fn f() -> GfPrime {
    GfPrime::default_field()
}

fn oracle_a2a<F: Field>(f: &F, c: &Mat, inputs: &[Packet]) -> Vec<Packet> {
    (0..c.cols)
        .map(|j| {
            let mut acc = pkt_zero(inputs[0].len());
            for r in 0..c.rows {
                pkt_add_scaled(f, &mut acc, c[(r, j)], &inputs[r]);
            }
            acc
        })
        .collect()
}

/// Fig. 2: K = 4, p = 1 — any `C ∈ F^{4×4}` in exactly 2 rounds; in round
/// 1 every processor receives `x_{k−1}` from `P_{k−1}`, in round 2 a
/// combined packet from `P_{k−2}`.
#[test]
fn fig2_k4_p1() {
    let f = f();
    let c = Arc::new(Mat::random(&f, 4, 4, 42));
    let inputs: Vec<Packet> = (0..4u64).map(|i| vec![f.elem(10 * i + 1)]).collect();
    let mut ps = PrepareShoot::new(f, (0..4).collect(), 1, c.clone(), inputs.clone());
    let mut sim = Sim::with_trace(1);
    let rep = run(&mut sim, &mut ps).unwrap();
    assert_eq!(rep.c1, 2);
    assert_eq!(rep.c2, 2);
    // Round 1: every P_k receives from its neighbour at distance 1.
    let r1 = trace::edges_of_round(&sim.trace, 1);
    assert_eq!(r1, vec![(0, 1), (1, 2), (2, 3), (3, 0)]);
    // Round 2: from distance 2.
    let r2 = trace::edges_of_round(&sim.trace, 2);
    assert_eq!(r2, vec![(0, 2), (1, 3), (2, 0), (3, 1)]);
    let outs = ps.outputs();
    let want = oracle_a2a(&f, &c, &inputs);
    for k in 0..4 {
        assert_eq!(outs[&k], want[k]);
    }
}

/// Fig. 3: K = 25, R = 4, p = 1 — sources in a 4×7 grid, borrowed sinks
/// complete the last column, row-wise reduces deliver to the sinks.
#[test]
fn fig3_k25_r4() {
    let f = f();
    let a = Arc::new(Mat::random(&f, 25, 4, 3));
    let inputs: Vec<Packet> = (0..25u64).map(|i| vec![f.elem(i + 1)]).collect();
    let mut job =
        SystematicEncode::new(f, a.clone(), inputs.clone(), 1, A2aAlgo::Universal).unwrap();
    let rep = run(&mut Sim::new(1), &mut job).unwrap();
    assert_eq!(job.coded(), oracle_a2a(&f, &a, &inputs));
    // Phase 1 on 4×4 blocks costs ⌈log2 4⌉ = 2 rounds; phase 2 reduces
    // over M+1 = 8 nodes in 3 rounds.
    assert_eq!(rep.c1, 2 + 3);
}

/// Fig. 4: K = 4, R = 25, p = 1 — sinks in a 4×7 grid, sources broadcast
/// then columns encode.
#[test]
fn fig4_k4_r25() {
    let f = f();
    let a = Arc::new(Mat::random(&f, 4, 25, 4));
    let inputs: Vec<Packet> = (0..4u64).map(|i| vec![f.elem(i + 3)]).collect();
    let mut job =
        SystematicEncode::new(f, a.clone(), inputs.clone(), 1, A2aAlgo::Universal).unwrap();
    let rep = run(&mut Sim::new(1), &mut job).unwrap();
    assert_eq!(job.coded(), oracle_a2a(&f, &a, &inputs));
    // Phase 1: broadcast over M+1 = 8 nodes (3 rounds); phase 2: 4×4
    // blocks (2 rounds).
    assert_eq!(rep.c1, 3 + 2);
}

/// Figs. 5–7: K = 65, p = 2 — L = 4, T_p = T_s = 2, m = 9, n = 8:
/// prepare covers `R_k^- = {k, …, k−8}`, shoot reduces the stride-9
/// classes, and the eq. (4) correction fires (m·n = 72 > 65).
#[test]
fn fig5_6_7_k65_p2() {
    let f = f();
    let k = 65usize;
    let c = Arc::new(Mat::random(&f, k, k, 65));
    let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![f.elem(i * 3 + 2)]).collect();
    let mut ps = PrepareShoot::new(f, (0..k).collect(), 2, c.clone(), inputs.clone());
    let mut sim = Sim::with_trace(2);
    let rep = run(&mut sim, &mut ps).unwrap();
    assert_eq!(rep.c1, 4); // L = ⌈log3 65⌉ = 4
    let by_round = trace::by_round(&sim.trace);
    // Prepare round 1: single packets over distances ρ·3^{T_p−1} = {3, 6}.
    assert!(by_round[0].iter().all(|e| e.elems == 1));
    assert!(by_round[0]
        .iter()
        .all(|e| [3, 6].contains(&((e.dst + k - e.src) % k))));
    // Prepare round 2: memory holds 3 packets; distances {1, 2}.
    assert!(by_round[1].iter().all(|e| e.elems == 3));
    assert!(by_round[1]
        .iter()
        .all(|e| [1, 2].contains(&((e.dst + k - e.src) % k))));
    // Shoot round 1 (m = 9, n = 8): each port carries the offsets with
    // digit_0 = ρ — ⌊8/3⌋..⌈8/3⌉ packets over distances {9, 18}.
    assert!(by_round[2].iter().all(|e| e.elems == 2 || e.elems == 3));
    assert!(by_round[2]
        .iter()
        .all(|e| [9, 18].contains(&((e.dst + k - e.src) % k))));
    // Shoot round 2: digit_1 over distances {27, 54}.
    assert!(by_round[3]
        .iter()
        .all(|e| [27, 54].contains(&((e.dst + k - e.src) % k))));
    let outs = ps.outputs();
    let want = oracle_a2a(&f, &c, &inputs);
    for kk in 0..k {
        assert_eq!(outs[&kk], want[kk], "proc {kk}");
    }
}

/// Fig. 8: K = 9, P = 3 — the two trees: every child element is a cube
/// root of its parent, and the DFT A2A produces f(β^{rev(k)}).
#[test]
fn fig8_k9_p3_trees() {
    // Needs 9 | q−1: q = 37 (36 = 4·9).
    let f = GfPrime::new(37).unwrap();
    let beta = f.root_of_unity(9).unwrap();
    // Element tree (right of Fig. 8): root hosts γ = 1, children are
    // distinct cube roots of their parent.
    assert_eq!(dft::gamma(&f, beta, 9, 3, 0, 0), 1);
    let mut lvl1 = Vec::new();
    for low in 0..3u64 {
        let child = dft::gamma(&f, beta, 9, 3, 1, low);
        assert_eq!(f.pow(child, 3), 1);
        lvl1.push(child);
    }
    lvl1.dedup();
    assert_eq!(lvl1.len(), 3, "distinct cube roots");
    // Running the DFT A2A reproduces f(β^{rev(j)}) — and with
    // P = p+1 = 3, Corollary 1's optimal cost H = 2 rounds/elements.
    let k = 9usize;
    let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![f.elem(i + 1)]).collect();
    let mut d = DftA2A::new(f, (0..k).collect(), 2, 3, 2, inputs.clone(), false).unwrap();
    let rep = run(&mut Sim::new(2), &mut d).unwrap();
    assert_eq!((rep.c1, rep.c2), (2, 2));
    let outs = d.outputs();
    for j in 0..k {
        let pt = f.pow(beta, dft::digit_reverse(j as u64, 3, 2));
        let mut want = 0u64;
        for (i, x) in inputs.iter().enumerate() {
            want = f.add(want, f.mul(x[0], f.pow(pt, i as u64)));
        }
        assert_eq!(outs[&j][0], want, "f(β^rev({j}))");
    }
}

/// Fig. 9: non-systematic K = 4, R = 27 — 6 full sink columns plus 3
/// stacked sinks.
#[test]
fn fig9_k4_r27() {
    let f = f();
    let g = Arc::new(Mat::random(&f, 4, 31, 9));
    let inputs: Vec<Packet> = (0..4u64).map(|i| vec![f.elem(2 * i + 1)]).collect();
    let mut job = NonSystematicEncode::new(f, g.clone(), inputs.clone(), 1).unwrap();
    let rep = run(&mut Sim::new(1), &mut job).unwrap();
    assert_eq!(job.codeword(), oracle_a2a(&f, &g, &inputs));
    // Phase 1: broadcast over 7 nodes (3 rounds); phase 2: column A2As of
    // size ≤ 5 (3 rounds at p = 1).
    assert_eq!(rep.c1, 3 + 3);
}

/// Fig. 6 depicts the two-round dissemination of `x_0` (distances {3,6}
/// then {1,2}) inside the K = 65, p = 2 prepare phase; its per-round
/// pattern is asserted in [`fig5_6_7_k65_p2`]. Here: the degenerate K = 9
/// case has a single prepare round at distances {1, 2}.
#[test]
fn fig6_dissemination_k9_p2() {
    let f = f();
    let k = 9usize;
    let c = Arc::new(Mat::random(&f, k, k, 6));
    let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![f.elem(i + 1)]).collect();
    let mut ps = PrepareShoot::new(f, (0..k).collect(), 2, c, inputs);
    let mut sim = Sim::with_trace(2);
    let rep = run(&mut sim, &mut ps).unwrap();
    assert_eq!(rep.c1, 2); // L = 2: T_p = 1, T_s = 1
    let r1 = trace::edges_of_round(&sim.trace, 1);
    assert!(r1.contains(&(0, 1)) && r1.contains(&(0, 2)));
    assert_eq!(ipow(3, 2), 9);
}

//! Property-based invariants (seeded random sweeps — the offline build
//! has no proptest; `dce::util::Rng` provides deterministic generation
//! with printed seeds for reproduction).
//!
//! Invariants covered:
//! * every A2A algorithm computes `x·C` exactly, for random `C`, all
//!   shapes/ports/fields;
//! * `C1` optimality (Lemma 1) and the `C2` lower bound (Lemma 2) hold on
//!   every run;
//! * port discipline: the engine never observes > p sends/receives (it
//!   would error — absence of errors is the assertion);
//! * frameworks agree with the direct matrix oracle for every (K, R)
//!   aspect ratio;
//! * RS decode succeeds from *every* K-subset on small codes (exhaustive)
//!   and random subsets on larger ones;
//! * draw-and-loose ∘ inverse = identity.

use dce::codes::{structured::disjoint_family, GrsCode};
use dce::collectives::{DrawLoose, MultiReduce, PrepareShoot};
use dce::framework::{costs, A2aAlgo, NonSystematicEncode, SystematicEncode};
use dce::gf::{Field, Gf2e, GfPrime, Mat};
use dce::net::{pkt_add_scaled, pkt_zero, run, Collective, Packet, Sim};
use dce::util::Rng;
use std::sync::Arc;

fn oracle<F: Field>(f: &F, c: &Mat, inputs: &[Packet]) -> Vec<Packet> {
    (0..c.cols)
        .map(|j| {
            let mut acc = pkt_zero(inputs[0].len());
            for r in 0..c.rows {
                pkt_add_scaled(f, &mut acc, c[(r, j)], &inputs[r]);
            }
            acc
        })
        .collect()
}

fn rand_inputs<F: Field>(f: &F, k: usize, w: usize, rng: &mut Rng) -> Vec<Packet> {
    (0..k)
        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
        .collect()
}

#[test]
fn prepare_shoot_random_shapes_prime_field() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xA11CE);
    for trial in 0..60 {
        let k = rng.range(1, 120) as usize;
        let p = rng.range(1, 5) as usize;
        let w = rng.range(1, 4) as usize;
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let inputs = rand_inputs(&f, k, w, &mut rng);
        let mut ps = PrepareShoot::new(f, (0..k).collect(), p, c.clone(), inputs.clone());
        let rep = run(&mut Sim::new(p), &mut ps)
            .unwrap_or_else(|e| panic!("trial {trial} K={k} p={p}: {e}"));
        let outs = ps.outputs();
        let want = oracle(&f, &c, &inputs);
        for kk in 0..k {
            assert_eq!(outs[&kk], want[kk], "trial {trial} K={k} p={p} proc {kk}");
        }
        // Lemma 1: C1 is exactly the optimum for K ≥ 2.
        assert_eq!(
            rep.c1,
            costs::lemma1_c1_lower_bound(k as u64, p as u64),
            "trial {trial} K={k} p={p}"
        );
        // Lemma 2: C2 respects the universal lower bound (W = 1 scale).
        if w == 1 && k >= 2 {
            let lb = costs::lemma2_c2_lower_bound(k as u64, p as u64).floor();
            assert!(
                rep.c2 as f64 >= lb - 1.0,
                "trial {trial} K={k} p={p}: C2={} < lb={lb}",
                rep.c2
            );
        }
        // Theorem 3's formula upper-bounds the measured C2 (exact at
        // K = (p+1)^L, smaller otherwise due to saturation).
        if w == 1 {
            let (_, c2f) = costs::theorem3_universal(k as u64, p as u64);
            assert!(rep.c2 <= c2f, "trial {trial} K={k} p={p}: {} > {c2f}", rep.c2);
        }
    }
}

#[test]
fn prepare_shoot_random_shapes_gf2e() {
    let f = Gf2e::new(8).unwrap();
    let mut rng = Rng::new(0xB0B);
    for trial in 0..25 {
        let k = rng.range(2, 60) as usize;
        let p = rng.range(1, 4) as usize;
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let inputs = rand_inputs(&f, k, 1, &mut rng);
        let mut ps = PrepareShoot::new(f.clone(), (0..k).collect(), p, c.clone(), inputs.clone());
        run(&mut Sim::new(p), &mut ps).unwrap();
        let outs = ps.outputs();
        let want = oracle(&f, &c, &inputs);
        for kk in 0..k {
            assert_eq!(outs[&kk], want[kk], "trial {trial} K={k} p={p}");
        }
    }
}

#[test]
fn multireduce_matches_prepare_shoot_everywhere() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xC0DE);
    for _ in 0..20 {
        let k = rng.range(2, 50) as usize;
        let p = rng.range(1, 4) as usize;
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let inputs = rand_inputs(&f, k, 1, &mut rng);
        let mut ps = PrepareShoot::new(f, (0..k).collect(), p, c.clone(), inputs.clone());
        let rep_ps = run(&mut Sim::new(p), &mut ps).unwrap();
        let mut mr = MultiReduce::new(f, (0..k).collect(), p, c, inputs);
        let rep_mr = run(&mut Sim::new(p), &mut mr).unwrap();
        assert_eq!(ps.outputs(), mr.outputs(), "K={k} p={p}");
        // The whole point of the paper: multi-reduce never beats
        // prepare-and-shoot in C2.
        assert!(rep_mr.c2 >= rep_ps.c2, "K={k} p={p}");
    }
}

#[test]
fn frameworks_all_aspect_ratios() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xF00D);
    for _ in 0..25 {
        let k = rng.range(1, 40) as usize;
        let r = rng.range(1, 40) as usize;
        let p = rng.range(1, 4) as usize;
        let a = Arc::new(Mat::random(&f, k, r, rng.next_u64()));
        let inputs = rand_inputs(&f, k, 2, &mut rng);
        let mut job =
            SystematicEncode::new(f, a.clone(), inputs.clone(), p, A2aAlgo::Universal)
                .unwrap();
        run(&mut Sim::new(p), &mut job)
            .unwrap_or_else(|e| panic!("K={k} R={r} p={p}: {e}"));
        assert_eq!(job.coded(), oracle(&f, &a, &inputs), "K={k} R={r} p={p}");
    }
}

#[test]
fn nonsystematic_all_aspect_ratios() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xFEED);
    for _ in 0..20 {
        let k = rng.range(1, 25) as usize;
        let r = rng.range(0, 30) as usize;
        // Leftover distribution requires L ≤ ⌊R/K⌋ when K ≤ R.
        if k <= r && r % k != 0 && (r % k) > r / k {
            continue;
        }
        if k + r < 2 {
            continue;
        }
        let p = rng.range(1, 3) as usize;
        let g = Arc::new(Mat::random(&f, k, k + r, rng.next_u64()));
        let inputs = rand_inputs(&f, k, 1, &mut rng);
        let mut job = NonSystematicEncode::new(f, g.clone(), inputs.clone(), p).unwrap();
        run(&mut Sim::new(p), &mut job)
            .unwrap_or_else(|e| panic!("K={k} R={r} p={p}: {e}"));
        assert_eq!(job.codeword(), oracle(&f, &g, &inputs), "K={k} R={r} p={p}");
    }
}

#[test]
fn rs_decode_every_subset_exhaustive_small() {
    // [7, 4] code: all C(7,4) = 35 subsets decode.
    let f = GfPrime::default_field();
    let code = GrsCode::plain(&f, (1..=4).collect(), (10..13).collect()).unwrap();
    let x = vec![11u64, 0, 786432, 5];
    let cw = code.encode(&f, &x);
    let n = code.n();
    for mask in 0u32..(1 << n) {
        if mask.count_ones() as usize != code.k() {
            continue;
        }
        let coords: Vec<(usize, u64)> = (0..n)
            .filter(|i| mask & (1 << i) != 0)
            .map(|i| (i, cw[i]))
            .collect();
        assert_eq!(code.decode(&f, &coords).unwrap(), x, "mask {mask:b}");
    }
}

#[test]
fn draw_loose_inverse_is_identity() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xD1CE);
    for n in [8usize, 16, 24, 12] {
        let fam = disjoint_family(&f, n, 2, 1).unwrap();
        let sp = &fam[0];
        let inputs = rand_inputs(&f, n, 1, &mut rng);
        let mut fwd = DrawLoose::new(f, (0..n).collect(), 1, sp, inputs.clone(), false).unwrap();
        run(&mut Sim::new(1), &mut fwd).unwrap();
        let mid: Vec<Packet> = (0..n).map(|i| fwd.outputs()[&i].clone()).collect();
        let mut inv = DrawLoose::new(f, (0..n).collect(), 1, sp, mid, true).unwrap();
        run(&mut Sim::new(1), &mut inv).unwrap();
        let back: Vec<Packet> = (0..n).map(|i| inv.outputs()[&i].clone()).collect();
        assert_eq!(back, inputs, "n={n}");
    }
}

#[test]
fn structured_rs_specific_universal_and_baseline_agree() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0x5EED);
    for (k, r) in [(8usize, 8usize), (16, 8), (8, 16), (32, 8), (8, 32)] {
        let code = GrsCode::structured(&f, k, r, 2).unwrap();
        let a = Arc::new(code.parity_matrix(&f));
        let inputs = rand_inputs(&f, k, 2, &mut rng);
        let mut spec = SystematicEncode::new_rs(f, &code, inputs.clone(), 1).unwrap();
        run(&mut Sim::new(1), &mut spec).unwrap();
        let mut univ =
            SystematicEncode::new(f, a.clone(), inputs.clone(), 1, A2aAlgo::Universal).unwrap();
        run(&mut Sim::new(1), &mut univ).unwrap();
        let mut mr =
            SystematicEncode::new(f, a.clone(), inputs.clone(), 1, A2aAlgo::MultiReduce)
                .unwrap();
        run(&mut Sim::new(1), &mut mr).unwrap();
        assert_eq!(spec.coded(), univ.coded(), "K={k} R={r}");
        assert_eq!(univ.coded(), mr.coded(), "K={k} R={r}");
        assert_eq!(spec.coded(), oracle(&f, &a, &inputs), "K={k} R={r}");
    }
}

#[test]
fn universality_scheduling_is_matrix_independent() {
    // The defining property of a *universal* algorithm (§I, §IV): the
    // scheduling — who talks to whom, with what message sizes, in which
    // round — is fixed before the matrix is known; only the coding
    // scheme (coefficients) varies. Run prepare-and-shoot on several
    // unrelated matrices and assert bit-identical traces.
    let f = GfPrime::default_field();
    for (k, p) in [(65usize, 2usize), (40, 1), (27, 3)] {
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![i + 1]).collect();
        let mut traces = Vec::new();
        for seed in [1u64, 999, 31337] {
            let c = Arc::new(Mat::random(&f, k, k, seed));
            let mut ps = PrepareShoot::new(f, (0..k).collect(), p, c, inputs.clone());
            let mut sim = dce::net::Sim::with_trace(p);
            run(&mut sim, &mut ps).unwrap();
            traces.push(sim.trace);
        }
        assert_eq!(traces[0], traces[1], "K={k} p={p}");
        assert_eq!(traces[1], traces[2], "K={k} p={p}");
    }
    // By contrast the specific algorithms fix the matrix family up
    // front — universality subsumes them (Remark 5), not vice versa.
}

#[test]
fn dft_a2a_random_ports_and_radices() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xDF7);
    // All (P, H) with P^H | 2^18 (q−1 = 2^18·3) small enough to run.
    for (p_base, h) in [(2u64, 1u32), (2, 5), (2, 7), (4, 3), (8, 2), (16, 1), (64, 1)] {
        let k = dce::util::ipow(p_base, h) as usize;
        let ports = rng.range(1, 4) as usize;
        let inputs = rand_inputs(&f, k, 1, &mut rng);
        let mut d = dce::collectives::DftA2A::new(
            f,
            (0..k).collect(),
            ports,
            p_base,
            h,
            inputs.clone(),
            false,
        )
        .unwrap();
        run(&mut Sim::new(ports), &mut d).unwrap();
        let m = dce::collectives::DftA2A::matrix(&f, p_base, h, false).unwrap();
        let outs = d.outputs();
        let want = oracle(&f, &m, &inputs);
        for kk in 0..k {
            assert_eq!(outs[&kk], want[kk], "P={p_base} H={h} p={ports} proc {kk}");
        }
    }
}

#[test]
fn draw_loose_with_arbitrary_injective_phi() {
    // Theorem 5 claims ((q−1)/Z choose M) distinct matrices: any injective
    // φ works, not just the identity range.
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xF1);
    let n = 16usize;
    for _ in 0..10 {
        let h = dce::codes::StructuredPoints::max_h(&f, n as u64, 2);
        let m = n / dce::util::ipow(2, h) as usize;
        let cap = (786433 - 1) / dce::util::ipow(2, h);
        let mut phi: Vec<u64> = Vec::new();
        while phi.len() < m {
            let c = rng.below(cap);
            if !phi.contains(&c) {
                phi.push(c);
            }
        }
        let sp = dce::codes::StructuredPoints::new(&f, n, 2, phi).unwrap();
        let inputs = rand_inputs(&f, n, 1, &mut rng);
        let mut dl = DrawLoose::new(f, (0..n).collect(), 1, &sp, inputs.clone(), false).unwrap();
        run(&mut Sim::new(1), &mut dl).unwrap();
        let mat = DrawLoose::matrix(&f, &sp, false).unwrap();
        let outs = dl.outputs();
        let want = oracle(&f, &mat, &inputs);
        for kk in 0..n {
            assert_eq!(outs[&kk], want[kk]);
        }
    }
}

#[test]
fn cauchy_a2a_multi_port_sweep() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xCA);
    for (n, ports) in [(8usize, 1usize), (16, 2), (16, 3), (32, 2)] {
        let fam = disjoint_family(&f, n, 2, 2).unwrap();
        let pre: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
        let post: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
        let inputs = rand_inputs(&f, n, 2, &mut rng);
        let mut ca = dce::collectives::CauchyA2A::new(
            f,
            (0..n).collect(),
            ports,
            &fam[0],
            &fam[1],
            pre.clone(),
            post.clone(),
            inputs.clone(),
        )
        .unwrap();
        run(&mut Sim::new(ports), &mut ca).unwrap();
        let m = dce::collectives::CauchyA2A::matrix(&f, &fam[0], &fam[1], &pre, &post);
        let outs = ca.outputs();
        let want = oracle(&f, &m, &inputs);
        for kk in 0..n {
            assert_eq!(outs[&kk], want[kk], "n={n} p={ports}");
        }
    }
}

#[test]
fn gf2e_framework_end_to_end() {
    // Storage-style: GF(256) systematic encode through the framework.
    let f = Gf2e::new(8).unwrap();
    let mut rng = Rng::new(0x256);
    for (k, r) in [(12usize, 4usize), (4, 12), (9, 9)] {
        let a = Arc::new(Mat::random(&f, k, r, rng.next_u64()));
        let inputs = rand_inputs(&f, k, 3, &mut rng);
        let mut job =
            SystematicEncode::new(f.clone(), a.clone(), inputs.clone(), 2, A2aAlgo::Universal)
                .unwrap();
        run(&mut Sim::new(2), &mut job).unwrap();
        assert_eq!(job.coded(), oracle(&f, &a, &inputs), "K={k} R={r}");
    }
}

#[test]
fn gf2e_structured_draw_loose() {
    // q−1 = 255 = 3·5·17: radix 3 gives H = 1 — the specific algorithm
    // works over binary extension fields too.
    let f = Gf2e::new(8).unwrap();
    let n = 6usize; // M = 2, Z = 3
    let sp = dce::codes::StructuredPoints::new(&f, n, 3, vec![0, 1]).unwrap();
    let mut rng = Rng::new(1);
    let inputs = rand_inputs(&f, n, 1, &mut rng);
    let mut dl =
        DrawLoose::new(f.clone(), (0..n).collect(), 1, &sp, inputs.clone(), false).unwrap();
    run(&mut Sim::new(1), &mut dl).unwrap();
    let mat = DrawLoose::matrix(&f, &sp, false).unwrap();
    let outs = dl.outputs();
    let want = oracle(&f, &mat, &inputs);
    for kk in 0..n {
        assert_eq!(outs[&kk], want[kk]);
    }
}

#[test]
fn lemma2_baseline_argument_multireduce_never_below_bound() {
    // Lemma 2 applies to *any* universal algorithm — check the baseline
    // also respects it (sanity of the bound, not just our algorithm).
    let f = GfPrime::default_field();
    for k in [16usize, 64, 128] {
        let c = Arc::new(Mat::random(&f, k, k, 2));
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![i + 1]).collect();
        let mut mr = MultiReduce::new(f, (0..k).collect(), 1, c, inputs);
        let rep = run(&mut Sim::new(1), &mut mr).unwrap();
        assert!(rep.c2 as f64 >= costs::lemma2_c2_lower_bound(k as u64, 1));
    }
}

#[test]
fn gf2e_every_a2a_variant_matches_oracle() {
    // GF(256) through every all-to-all encode family, with W > 1 so the
    // flat-buffer path carries multi-element packets (q−1 = 255 = 3·5·17).
    let f = Gf2e::new(8).unwrap();
    let mut rng = Rng::new(0x2E6);
    // Universal + baseline.
    for (k, p, w) in [(13usize, 2usize, 3usize), (16, 1, 2), (40, 3, 1), (1, 1, 2)] {
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let inputs = rand_inputs(&f, k, w, &mut rng);
        let want = oracle(&f, &c, &inputs);
        let mut ps = PrepareShoot::new(f.clone(), (0..k).collect(), p, c.clone(), inputs.clone());
        run(&mut Sim::new(p), &mut ps).unwrap();
        let mut mr = MultiReduce::new(f.clone(), (0..k).collect(), p, c, inputs);
        run(&mut Sim::new(p), &mut mr).unwrap();
        for kk in 0..k {
            assert_eq!(ps.outputs()[&kk], want[kk], "ps K={k} p={p} w={w}");
            assert_eq!(mr.outputs()[&kk], want[kk], "mr K={k} p={p} w={w}");
        }
    }
    // DFT: every prime-power radix dividing 255, plus the composite 15.
    for (p_base, h) in [(3u64, 1u32), (5, 1), (15, 1), (17, 1)] {
        let k = dce::util::ipow(p_base, h) as usize;
        let inputs = rand_inputs(&f, k, 2, &mut rng);
        let mut d = dce::collectives::DftA2A::new(
            f.clone(),
            (0..k).collect(),
            2,
            p_base,
            h,
            inputs.clone(),
            false,
        )
        .unwrap();
        run(&mut Sim::new(2), &mut d).unwrap();
        let m = dce::collectives::DftA2A::matrix(&f, p_base, h, false).unwrap();
        let want = oracle(&f, &m, &inputs);
        for kk in 0..k {
            assert_eq!(d.outputs()[&kk], want[kk], "dft P={p_base}");
        }
    }
    // Draw-and-loose and the Cauchy two-pass, on structured GF(256) points.
    let n = 6usize; // M = 2, Z = 3
    let fam = disjoint_family(&f, n, 3, 2).unwrap();
    let inputs = rand_inputs(&f, n, 2, &mut rng);
    let mut dl =
        DrawLoose::new(f.clone(), (0..n).collect(), 1, &fam[0], inputs.clone(), false).unwrap();
    run(&mut Sim::new(1), &mut dl).unwrap();
    let mat = DrawLoose::matrix(&f, &fam[0], false).unwrap();
    let want = oracle(&f, &mat, &inputs);
    for kk in 0..n {
        assert_eq!(dl.outputs()[&kk], want[kk], "draw-loose gf2e");
    }
    let pre: Vec<u64> = (0..n as u64).map(|_| rng.range(1, 256)).collect();
    let post: Vec<u64> = (0..n as u64).map(|_| rng.range(1, 256)).collect();
    let mut ca = dce::collectives::CauchyA2A::new(
        f.clone(),
        (0..n).collect(),
        1,
        &fam[0],
        &fam[1],
        pre.clone(),
        post.clone(),
        inputs.clone(),
    )
    .unwrap();
    run(&mut Sim::new(1), &mut ca).unwrap();
    let m = dce::collectives::CauchyA2A::matrix(&f, &fam[0], &fam[1], &pre, &post);
    let want = oracle(&f, &m, &inputs);
    for kk in 0..n {
        assert_eq!(ca.outputs()[&kk], want[kk], "cauchy gf2e");
    }
}

#[test]
fn degenerate_shapes_are_exact() {
    // K = 1 / R = 1 / W = 1 / p = 1 corners through the frameworks and
    // every primitive collective that admits them.
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xD0D0);
    for (k, r) in [(1usize, 1usize), (1, 5), (5, 1), (1, 12), (12, 1)] {
        let a = Arc::new(Mat::random(&f, k, r, rng.next_u64()));
        let inputs = rand_inputs(&f, k, 1, &mut rng);
        let mut job =
            SystematicEncode::new(f, a.clone(), inputs.clone(), 1, A2aAlgo::Universal).unwrap();
        run(&mut Sim::new(1), &mut job).unwrap();
        assert_eq!(job.coded(), oracle(&f, &a, &inputs), "sys K={k} R={r}");
    }
    for (k, r) in [(1usize, 1usize), (5, 1), (12, 1), (1, 4)] {
        let g = Arc::new(Mat::random(&f, k, k + r, rng.next_u64()));
        let inputs = rand_inputs(&f, k, 1, &mut rng);
        let mut job = NonSystematicEncode::new(f, g.clone(), inputs.clone(), 1).unwrap();
        run(&mut Sim::new(1), &mut job).unwrap();
        assert_eq!(job.codeword(), oracle(&f, &g, &inputs), "nonsys K={k} R={r}");
    }
    // The smallest possible engine runs: K ∈ {1, 2}.
    for k in [1usize, 2] {
        let c = Arc::new(Mat::random(&f, k, k, 3));
        let inputs = rand_inputs(&f, k, 1, &mut rng);
        let mut ps = PrepareShoot::new(f, (0..k).collect(), 1, c.clone(), inputs.clone());
        run(&mut Sim::new(1), &mut ps).unwrap();
        let want = oracle(&f, &c, &inputs);
        for kk in 0..k {
            assert_eq!(ps.outputs()[&kk], want[kk], "ps K={k}");
        }
        let mut mr = MultiReduce::new(f, (0..k).collect(), 1, c, inputs);
        run(&mut Sim::new(1), &mut mr).unwrap();
        for kk in 0..k {
            assert_eq!(mr.outputs()[&kk], want[kk], "mr K={k}");
        }
    }
    // Draw-and-loose degenerates to a 1×1 universal at n = 1 (H = 0).
    let sp = dce::codes::StructuredPoints::new(&f, 1, 2, vec![0]).unwrap();
    let inputs = rand_inputs(&f, 1, 1, &mut rng);
    let mut dl = DrawLoose::new(f, vec![0], 1, &sp, inputs.clone(), false).unwrap();
    run(&mut Sim::new(1), &mut dl).unwrap();
    let mat = DrawLoose::matrix(&f, &sp, false).unwrap();
    assert_eq!(dl.outputs()[&0], oracle(&f, &mat, &inputs)[0]);
}

#[test]
fn specific_a2a_wide_payloads() {
    // The flat-buffer path with W > 1 for every specific A2A variant
    // (Remark 2: same scheduling, per-element packets).
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0x77);
    for (p_base, h, w) in [(2u64, 3u32, 4usize), (4, 2, 3)] {
        let k = dce::util::ipow(p_base, h) as usize;
        let inputs = rand_inputs(&f, k, w, &mut rng);
        let mut d = dce::collectives::DftA2A::new(
            f,
            (0..k).collect(),
            1,
            p_base,
            h,
            inputs.clone(),
            false,
        )
        .unwrap();
        run(&mut Sim::new(1), &mut d).unwrap();
        let m = dce::collectives::DftA2A::matrix(&f, p_base, h, false).unwrap();
        let want = oracle(&f, &m, &inputs);
        for kk in 0..k {
            assert_eq!(d.outputs()[&kk], want[kk], "dft P={p_base} w={w}");
        }
    }
    for (n, w) in [(16usize, 4usize), (24, 2)] {
        let fam = disjoint_family(&f, n, 2, 1).unwrap();
        let inputs = rand_inputs(&f, n, w, &mut rng);
        let mut dl =
            DrawLoose::new(f, (0..n).collect(), 1, &fam[0], inputs.clone(), false).unwrap();
        run(&mut Sim::new(1), &mut dl).unwrap();
        let mat = DrawLoose::matrix(&f, &fam[0], false).unwrap();
        let want = oracle(&f, &mat, &inputs);
        for kk in 0..n {
            assert_eq!(dl.outputs()[&kk], want[kk], "dl n={n} w={w}");
        }
    }
    {
        let n = 16usize;
        let w = 3usize;
        let fam = disjoint_family(&f, n, 2, 2).unwrap();
        let pre: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
        let post: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
        let inputs = rand_inputs(&f, n, w, &mut rng);
        let mut ca = dce::collectives::CauchyA2A::new(
            f,
            (0..n).collect(),
            2,
            &fam[0],
            &fam[1],
            pre.clone(),
            post.clone(),
            inputs.clone(),
        )
        .unwrap();
        run(&mut Sim::new(2), &mut ca).unwrap();
        let m = dce::collectives::CauchyA2A::matrix(&f, &fam[0], &fam[1], &pre, &post);
        let want = oracle(&f, &m, &inputs);
        for kk in 0..n {
            assert_eq!(ca.outputs()[&kk], want[kk], "cauchy w={w}");
        }
    }
}

#[test]
fn payload_width_is_transparent() {
    // Remark 2: W > 1 multiplies C2 by exactly W and leaves C1 unchanged.
    let f = GfPrime::default_field();
    let k = 27usize;
    let c = Arc::new(Mat::random(&f, k, k, 1));
    let mut rng = Rng::new(3);
    let one = rand_inputs(&f, k, 1, &mut rng);
    let mut ps1 = PrepareShoot::new(f, (0..k).collect(), 2, c.clone(), one);
    let r1 = run(&mut Sim::new(2), &mut ps1).unwrap();
    let wide = rand_inputs(&f, k, 5, &mut rng);
    let mut ps5 = PrepareShoot::new(f, (0..k).collect(), 2, c, wide);
    let r5 = run(&mut Sim::new(2), &mut ps5).unwrap();
    assert_eq!(r1.c1, r5.c1);
    assert_eq!(r1.c2 * 5, r5.c2);
}

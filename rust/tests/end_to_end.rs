//! End-to-end: coordinator jobs across configurations, the encode
//! service, and config-file round trips.

use dce::coordinator::config::{CodeKind, VerifyMode};
use dce::coordinator::{EncodeJob, EncodeService, ExecOptions, JobConfig};
use dce::framework::{AlgoRequest, PlanChoice};
use dce::gf::{Field, GfPrime};
use std::path::Path;

#[test]
fn jobs_across_the_config_matrix() {
    for (k, r, code, algo) in [
        (16usize, 4usize, CodeKind::RsStructured, AlgoRequest::Auto),
        (16, 4, CodeKind::RsStructured, AlgoRequest::Universal),
        (16, 4, CodeKind::RsStructured, AlgoRequest::MultiReduce),
        (16, 4, CodeKind::RsStructured, AlgoRequest::Direct),
        (8, 24, CodeKind::RsStructured, AlgoRequest::Auto),
        (10, 7, CodeKind::RsPlain, AlgoRequest::Auto),
        (7, 10, CodeKind::Random, AlgoRequest::Universal),
        (12, 12, CodeKind::Lagrange, AlgoRequest::Universal),
    ] {
        let cfg = JobConfig {
            k,
            r,
            w: 4,
            ports: 2,
            code,
            algorithm: algo,
            ..JobConfig::default()
        };
        let rep = EncodeJob::synthetic(cfg).unwrap().run(&ExecOptions::new()).unwrap();
        assert_eq!(
            rep.verified,
            Some(true),
            "K={k} R={r} {code:?} {algo:?} failed verification"
        );
    }
}

#[test]
fn auto_planner_is_cost_and_structure_aware() {
    // Large structured code + bandwidth-dominated model → specific.
    let cfg = JobConfig {
        k: 256,
        r: 256,
        w: 4,
        alpha: 1.0,
        beta: 1.0,
        ..JobConfig::default()
    };
    let rep = EncodeJob::synthetic(cfg).unwrap().run(&ExecOptions::new()).unwrap();
    assert_eq!(rep.choice, PlanChoice::RsSpecific);
    assert_eq!(rep.verified, Some(true));

    // Small code → universal despite the structure (Remark 8).
    let cfg = JobConfig {
        k: 16,
        r: 4,
        w: 1,
        ..JobConfig::default()
    };
    let rep = EncodeJob::synthetic(cfg).unwrap().run(&ExecOptions::new()).unwrap();
    assert_eq!(rep.choice, PlanChoice::Universal);

    // Unstructured points → universal is the only specific-free choice.
    let cfg = JobConfig {
        k: 10,
        r: 7,
        w: 1,
        code: CodeKind::RsPlain,
        ..JobConfig::default()
    };
    let rep = EncodeJob::synthetic(cfg).unwrap().run(&ExecOptions::new()).unwrap();
    assert_eq!(rep.choice, PlanChoice::Universal);
}

#[test]
fn config_file_roundtrip() {
    let dir = std::env::temp_dir().join("dce_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("job.conf");
    std::fs::write(
        &path,
        "k = 12\nr = 4\nw = 8\nports = 2\ncode = \"rs-structured\"\nverify = \"native\"\n",
    )
    .unwrap();
    let cfg = JobConfig::load(&path).unwrap();
    let rep = EncodeJob::synthetic(cfg).unwrap().run(&ExecOptions::new()).unwrap();
    assert_eq!(rep.verified, Some(true));
}

#[test]
fn encode_service_roundtrip() {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let f = GfPrime::default_field();
    let code = dce::codes::GrsCode::structured(&f, 16, 4, 2).unwrap();
    let parity = code.parity_matrix(&f);
    let svc = EncodeService::start(&f, &parity, artifacts, 64, 2, 8).unwrap();
    // Submit a few batches, including a ragged width (chunking path).
    let mut rng = dce::util::Rng::new(5);
    let mut pending = Vec::new();
    for w in [64usize, 100, 64, 17] {
        let x: Vec<Vec<u64>> = (0..16)
            .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
            .collect();
        pending.push((x.clone(), svc.submit(x).unwrap()));
    }
    for (x, rx) in pending {
        let resp = rx.recv().unwrap();
        let y = resp.y.expect("encode ok");
        assert_eq!(y.len(), 4);
        // Oracle check.
        let w = x[0].len();
        for (j, row) in y.iter().enumerate() {
            assert_eq!(row.len(), w);
            for c in 0..w {
                let mut want = 0u64;
                for i in 0..16 {
                    want = f.add(want, f.mul(parity[(i, j)], x[i][c]));
                }
                assert_eq!(row[c], want, "sink {j} col {c}");
            }
        }
    }
    assert_eq!(svc.metrics.counter("requests"), 4);
    svc.shutdown();
}

#[test]
fn pjrt_verified_job_when_artifacts_present() {
    if !Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cfg = JobConfig {
        k: 64,
        r: 16,
        w: 256,
        ports: 2,
        verify: VerifyMode::Pjrt,
        ..JobConfig::default()
    };
    let rep = EncodeJob::synthetic(cfg).unwrap().run(&ExecOptions::new()).unwrap();
    assert_eq!(rep.verified, Some(true));
}

//! Serving-tier integration tests: [`BatchPolicy`] edge cases through
//! the public API, wire-framed round trips, and typed overload
//! behavior. The unit tests in `coordinator::service` cover the
//! dispatcher internals; these exercise the same guarantees the way an
//! embedding application would see them.

use dce::coordinator::{
    verify, BatchPolicy, EncodeJob, EncodeService, ExecOptions, JobConfig, PlanCache,
    ServeRejection,
    WireClient, WireServer,
};
use dce::gf::Field;
use dce::util::Rng;
use std::time::{Duration, Instant};

fn test_cfg(k: usize, r: usize) -> JobConfig {
    JobConfig {
        k,
        r,
        w: 4,
        ..JobConfig::default()
    }
}

fn payload(cfg: &JobConfig, rng: &mut Rng, w: usize) -> Vec<Vec<u64>> {
    let f = cfg.any_field().unwrap();
    (0..cfg.k)
        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
        .collect()
}

/// `max_delay == 0` degenerates to request-at-a-time serving: every
/// response still bit-matches the direct encode path, and nothing
/// waits on a timer (the whole closed loop finishes far under any
/// polling floor).
#[test]
fn zero_delay_policy_serves_immediately_and_correctly() {
    let cfg = test_cfg(8, 4);
    let policy = BatchPolicy {
        max_batch: 16,
        max_delay: Duration::ZERO,
    };
    let svc = EncodeService::start_replay_with(&cfg, 1, 32, policy).unwrap();
    let oracle = EncodeJob::synthetic(cfg.clone()).unwrap();
    let cache = PlanCache::new();
    let mut rng = Rng::new(11);
    // Warm the plan, then time 10 sequential round trips: with no
    // timer in the path they complete in milliseconds, not in
    // 10 × any poll interval.
    let _ = svc.submit(payload(&cfg, &mut rng, 3)).unwrap().recv().unwrap();
    let t0 = Instant::now();
    for _ in 0..10 {
        let x = payload(&cfg, &mut rng, 3);
        let y = svc.submit(x.clone()).unwrap().recv().unwrap().y.unwrap();
        assert_eq!(y, oracle.encode(&cache, &[&x], &ExecOptions::cached(&cache)).unwrap().coded.remove(0));
    }
    assert!(
        t0.elapsed() < Duration::from_millis(500),
        "zero-delay policy hit a poll floor: {:?}",
        t0.elapsed()
    );
    svc.shutdown();
}

/// `max_batch == 1` never co-batches: queued same-width requests are
/// each served in their own columnar pass.
#[test]
fn max_batch_one_never_co_batches() {
    let cfg = test_cfg(6, 3);
    let policy = BatchPolicy {
        max_batch: 1,
        max_delay: Duration::from_secs(5),
    };
    let svc = EncodeService::start_replay_with(&cfg, 1, 32, policy).unwrap();
    let mut rng = Rng::new(12);
    let n = 6usize;
    // Pile all n up before draining so co-batching *would* happen if
    // the occupancy cap were not honored.
    let pending: Vec<_> = (0..n)
        .map(|_| svc.submit(payload(&cfg, &mut rng, 4)).unwrap())
        .collect();
    for rx in pending {
        assert!(rx.recv().unwrap().y.is_ok());
    }
    let (batches, served, occupancy_max) = svc.metrics.batch_stats();
    assert_eq!(batches, n as u64, "every request got its own batch");
    assert_eq!(served, n as u64);
    assert_eq!(occupancy_max, 1);
    svc.shutdown();
}

/// Fewer queued requests than `max_batch`: the deadline (not
/// occupancy) fires the partial batch, well before the idle-wakeup
/// worst case, and the partial batch is served whole.
#[test]
fn deadline_fires_partial_batch_below_occupancy() {
    let cfg = test_cfg(6, 3);
    let policy = BatchPolicy {
        max_batch: 64,
        max_delay: Duration::from_millis(20),
    };
    let svc = EncodeService::start_replay_with(&cfg, 1, 128, policy).unwrap();
    let mut rng = Rng::new(13);
    // Warm the plan so compile time doesn't blur the deadline timing.
    let _ = svc.submit(payload(&cfg, &mut rng, 4)).unwrap().recv().unwrap();
    let pending: Vec<_> = (0..3)
        .map(|_| svc.submit(payload(&cfg, &mut rng, 4)).unwrap())
        .collect();
    let t0 = Instant::now();
    for rx in pending {
        assert!(rx.recv().unwrap().y.is_ok());
    }
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "a 20ms deadline left 3 requests waiting {:?}",
        t0.elapsed()
    );
    let (batches, served, occupancy_max) = svc.metrics.batch_stats();
    assert_eq!(served, 4);
    assert!(batches <= 4);
    assert!(occupancy_max <= 3, "64-cap batch can only hold what was queued");
    svc.shutdown();
}

/// The load-bearing equivalence: a deadline-fired *partial* batch
/// produces bit-identical bytes to the same payloads served as one
/// *full* occupancy-fired batch, and both match the direct
/// single-job path.
#[test]
fn partial_and_full_batches_are_bit_identical() {
    let cfg = test_cfg(10, 5);
    let mut rng = Rng::new(14);
    let payloads: Vec<_> = (0..6).map(|_| payload(&cfg, &mut rng, 7)).collect();
    let oracle = EncodeJob::synthetic(cfg.clone()).unwrap();
    let cache = PlanCache::new();
    let direct: Vec<_> = payloads
        .iter()
        .map(|x| oracle.encode(&cache, &[x], &ExecOptions::cached(&cache)).unwrap().coded.remove(0))
        .collect();

    // Full: occupancy fires one batch of exactly 6.
    let full = EncodeService::start_replay_with(
        &cfg,
        1,
        32,
        BatchPolicy {
            max_batch: 6,
            max_delay: Duration::from_secs(10),
        },
    )
    .unwrap();
    let pending: Vec<_> = payloads
        .iter()
        .map(|x| full.submit(x.clone()).unwrap())
        .collect();
    let full_ys: Vec<_> = pending
        .into_iter()
        .map(|rx| rx.recv().unwrap().y.unwrap())
        .collect();
    let (batches, served, occupancy_max) = full.metrics.batch_stats();
    assert_eq!((batches, served, occupancy_max), (1, 6, 6), "one full batch");
    full.shutdown();

    // Partial: a huge occupancy cap with a short deadline serves the
    // same payloads in deadline-fired fragments (sequential submits
    // with a sleep guarantee at least two fragments).
    let partial = EncodeService::start_replay_with(
        &cfg,
        1,
        32,
        BatchPolicy {
            max_batch: 1000,
            max_delay: Duration::from_millis(5),
        },
    )
    .unwrap();
    // Closed-loop submits: each request sits alone until its 5ms
    // deadline fires it, far below the 1000-occupancy cap.
    let mut partial_ys = Vec::new();
    for x in &payloads {
        let rx = partial.submit(x.clone()).unwrap();
        partial_ys.push(rx.recv().unwrap().y.unwrap());
    }
    let (batches, served, _) = partial.metrics.batch_stats();
    assert_eq!(served, 6);
    assert!(batches >= 2, "deadline never split the stream into fragments");
    partial.shutdown();

    assert_eq!(full_ys, partial_ys, "batch shape leaked into the bytes");
    assert_eq!(full_ys, direct, "batched bytes diverged from the direct path");
}

/// Mixed widths are never co-batched, observed from outside: random
/// per-width payloads all verify against the parity oracle (a crossed
/// batch would corrupt at least one row), with one compiled plan
/// reused across widths.
#[test]
fn mixed_widths_verify_against_the_parity_oracle() {
    let cfg = test_cfg(8, 4);
    let f = cfg.any_field().unwrap();
    let oracle = EncodeJob::synthetic(cfg.clone()).unwrap();
    let policy = BatchPolicy {
        max_batch: 8,
        max_delay: Duration::from_millis(10),
    };
    let svc = EncodeService::start_replay_with(&cfg, 2, 64, policy).unwrap();
    let mut rng = Rng::new(15);
    let widths = [2usize, 9, 2, 5, 9, 2, 5, 9];
    let pending: Vec<_> = widths
        .iter()
        .map(|&w| {
            let x = payload(&cfg, &mut rng, w);
            (x.clone(), svc.submit(x).unwrap())
        })
        .collect();
    for (x, rx) in pending {
        let y = rx.recv().unwrap().y.unwrap();
        assert_eq!(y.len(), cfg.r);
        assert!(verify::native(&f, &oracle.parity, &x, &y));
    }
    let (batches, served, _) = svc.metrics.batch_stats();
    assert_eq!(served, widths.len() as u64);
    // One cache lookup per columnar batch; single-flight waiters
    // resolve to hits, so exactly one compile ever happens.
    let (hits, misses) = svc.metrics.plan_cache();
    assert_eq!(misses, 1, "width-independent plan compiled once");
    assert_eq!(hits + misses, batches);
    svc.shutdown();
}

/// Overload is a typed, inspectable refusal on the non-blocking path —
/// and admission recovers as soon as the backlog drains.
#[test]
fn overload_rejects_typed_then_recovers() {
    let cfg = test_cfg(6, 3);
    let policy = BatchPolicy {
        max_batch: 64,
        // Park the backlog: nothing fires until the deadline.
        max_delay: Duration::from_secs(10),
    };
    let svc = EncodeService::start_replay_with(&cfg, 1, 2, policy).unwrap();
    let mut rng = Rng::new(16);
    let a = svc.try_submit_tenant(1, payload(&cfg, &mut rng, 3)).unwrap();
    let b = svc.try_submit_tenant(2, payload(&cfg, &mut rng, 3)).unwrap();
    let err = svc
        .try_submit_tenant(3, payload(&cfg, &mut rng, 3))
        .expect_err("third request must breach queue_depth = 2");
    match err.downcast_ref::<ServeRejection>() {
        Some(ServeRejection::Overloaded { global: true, limit: 2, .. }) => {}
        other => panic!("expected a typed global-overload refusal, got {other:?}"),
    }
    // Shutdown drains the parked backlog (zero dropped requests), and
    // the refusal above is visible in the admission counters.
    assert_eq!(svc.metrics.counter("admission_rejects"), 1);
    svc.shutdown();
    assert!(a.recv().unwrap().y.is_ok());
    assert!(b.recv().unwrap().y.is_ok());
}

/// A framed TCP round trip bit-matches the direct encode path, and a
/// wire client sees pipelined out-of-order completion by req_id.
#[test]
fn wire_round_trip_bit_matches_direct() {
    let mut cfg = test_cfg(8, 4);
    cfg.serve.max_delay_us = 200;
    let server = WireServer::start(&cfg, "127.0.0.1:0", 2).unwrap();
    let layout = dce::coordinator::wire_layout(&cfg).unwrap();
    let oracle = EncodeJob::synthetic(cfg.clone()).unwrap();
    let cache = PlanCache::new();
    let mut rng = Rng::new(17);
    let mut cli = WireClient::connect(server.local_addr(), layout).unwrap();
    let payloads: Vec<_> = (0..4)
        .map(|i| (i as u64, payload(&cfg, &mut rng, 3 + i)))
        .collect();
    for (id, x) in &payloads {
        cli.send(7, *id, x).unwrap();
    }
    let mut got = 0;
    while got < payloads.len() {
        let (id, y) = cli.recv().unwrap();
        let x = &payloads[id as usize].1;
        assert_eq!(
            y.unwrap(),
            oracle.encode(&cache, &[x], &ExecOptions::cached(&cache)).unwrap().coded.remove(0),
            "wire bytes diverged for req {id}"
        );
        got += 1;
    }
    server.shutdown();
}

//! Differential chaos properties over random shapes and random fault
//! mixes. The harness is self-contained (seeded by `PROPTEST_SEED`,
//! sized by `PROPTEST_CASES`, both honored like the real proptest
//! runner's) so the properties run on every `cargo test`; CI
//! additionally injects the `proptest` dev-dependency and re-runs the
//! same case body under `--features proptest-harness` with
//! shrinking-capable generation.
//!
//! Properties, for every generated (shape, spec, transport) triple:
//!
//! 1. `spawn_local_chaos` never panics and never errors — permanent
//!    faults degrade, they do not abort;
//! 2. the peer-side [`DegradedReport`](dce::net::DegradedReport) equals
//!    [`analyze_plan`](dce::net::analyze_plan) of the same spec;
//! 3. crashed ranks hold no outputs, and every untainted survivor is
//!    bit-identical to the healthy replay;
//! 4. transient-only specs leave outputs bit-identical with nothing
//!    dropped;
//! 5. on the coordinator, the replay and peer engines agree on
//!    recoverability: both repair to the same rows or both classify the
//!    spec as [`Error::Unrecoverable`](dce::Error).

use dce::coordinator::{EncodeJob, Engine, ExecOptions, JobConfig, PlanCache};
use dce::framework::{A2aAlgo, SystematicEncode};
use dce::gf::{Field, GfPrime, Mat};
use dce::net::peer::{spawn_local_chaos, RetryPolicy, ShardedPlan};
use dce::net::transport::{ChaosSpec, TransportKind};
use dce::net::{analyze_plan, exec, plan, Collective, FaultSpec, Packet, ProcId};
use dce::util::Rng;
use dce::Error;
use std::sync::Arc;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(20);

fn cases() -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(48)
}

fn prop_seed() -> u64 {
    std::env::var("PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDCE5_EED)
}

/// Tight backoffs keep partition-heavy cases fast; the attempt budget
/// still covers the worst transient stacking (stale dup + delay budget
/// of two + one reorder) with one attempt to spare.
fn fast_policy() -> RetryPolicy {
    RetryPolicy {
        max_attempts: 6,
        base_backoff: Duration::from_micros(200),
        max_backoff: Duration::from_millis(2),
    }
}

fn rand_inputs<F: Field>(f: &F, k: usize, w: usize, rng: &mut Rng) -> Vec<Packet> {
    (0..k)
        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
        .collect()
}

/// A random fault mix: each transient knob flips on independently at a
/// random rate, each rank crashes (mid-schedule or post-run) with low
/// probability, and an occasional partition or erasure cuts a link.
fn random_chaos(rng: &mut Rng, procs: &[ProcId], n_rounds: u64) -> ChaosSpec {
    let rounds = n_rounds.max(1);
    let mut spec = ChaosSpec::new().with_seed(rng.next_u64());
    if rng.below(2) == 0 {
        spec = spec.delay(rng.below(1001) as u16, 1 + rng.below(2) as u32);
    }
    if rng.below(2) == 0 {
        spec = spec.dup(rng.below(1001) as u16);
    }
    if rng.below(2) == 0 {
        spec = spec.reorder(rng.below(1001) as u16);
    }
    for &pid in procs {
        if rng.below(100) < 8 {
            spec = spec.crash_from(pid, rng.range(1, rounds + 1));
        } else if rng.below(100) < 4 {
            spec = spec.crash_after(pid);
        }
    }
    if procs.len() > 1 && rng.below(100) < 20 {
        let pick = rng.choose(procs.len(), 2);
        spec = spec.partition(procs[pick[0]], procs[pick[1]]);
    }
    if procs.len() > 1 && rng.below(100) < 20 {
        let pick = rng.choose(procs.len(), 2);
        let round = rng.range(1, rounds + 1);
        spec = spec.erase(round, procs[pick[0]], procs[pick[1]]);
    }
    spec
}

/// One property case: random systematic shape, random chaos spec, the
/// transport cycled by case index (mostly channels, every fourth pair
/// a ring or a socket mesh).
fn check_case(case: u64, rng: &mut Rng) {
    let f = GfPrime::default_field();
    let k = rng.range(1, 13) as usize;
    let r = rng.range(1, 5) as usize;
    let p = rng.range(1, 4) as usize;
    let w = rng.range(1, 4) as usize;
    let a = Arc::new(Mat::random(&f, k, r, rng.next_u64()));
    let build = move |ins: Vec<Packet>| -> Box<dyn Collective> {
        Box::new(SystematicEncode::new(f, a, ins, p, A2aAlgo::Universal).unwrap())
    };
    let compiled = plan::compile(p, k, |basis| Ok(build(basis))).unwrap();
    let inputs = rand_inputs(&f, k, w, rng);
    let rep = exec::replay(&compiled, &f, &inputs).unwrap();
    let owners: Vec<ProcId> = (0..compiled.n_inputs).collect();
    let sharded = ShardedPlan::new(&compiled, &f, &owners).unwrap();
    let chaos = random_chaos(rng, &sharded.procs, sharded.n_rounds as u64);
    let kind = match case % 8 {
        6 => TransportKind::SharedMem,
        7 => TransportKind::Tcp,
        _ => TransportKind::Channel,
    };
    let policy = fast_policy();
    let tag = format!("case {case}: K={k} R={r} p={p} w={w} over {kind}");

    let run = spawn_local_chaos(&sharded, &f, &inputs, kind, TIMEOUT, &chaos, &policy)
        .unwrap_or_else(|e| panic!("{tag}: {e:#}"));
    let expected = analyze_plan(&compiled, w, &chaos.to_fault_spec());
    assert_eq!(run.report, expected, "{tag}: report");
    for pid in &run.report.crashed {
        let kept = run.outputs.contains_key(pid);
        assert!(!kept, "{tag}: crashed rank {pid} kept an output");
    }
    for (pid, pkt) in &rep.outputs {
        if run.report.survives(*pid) {
            let got = run.outputs.get(pid);
            assert_eq!(got, Some(pkt), "{tag}: survivor {pid}");
        }
    }
    if chaos.is_transient_only() {
        assert_eq!(run.outputs, rep.outputs, "{tag}: transient outputs");
        assert_eq!(run.report.dropped_messages, 0, "{tag}");
    }
}

#[test]
fn random_shapes_and_specs_conform() {
    let mut rng = Rng::new(prop_seed());
    for case in 0..cases() {
        check_case(case, &mut rng);
    }
}

fn outcome<T>(r: &Result<T, Error>) -> &'static str {
    match r {
        Ok(_) => "ok",
        Err(Error::Unrecoverable(_)) => "unrecoverable",
        Err(Error::Transport(_)) => "transport error",
        Err(_) => "other error",
    }
}

#[test]
fn replay_and_peer_engines_agree_on_recoverability() {
    let cache = PlanCache::new();
    let mut rng = Rng::new(prop_seed() ^ 0x51DE);
    for case in 0..(cases() / 4).max(4) {
        let k = rng.range(2, 11) as usize;
        let r = rng.range(1, 5) as usize;
        let cfg = JobConfig {
            k,
            r,
            w: rng.range(1, 4) as usize,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let mut spec = FaultSpec::new();
        let mut injected = false;
        for pid in 0..(k + r) {
            if rng.below(100) < 15 {
                spec = spec.crash_from(pid, rng.range(1, 4));
                injected = true;
            }
        }
        if !injected {
            spec = spec.crash(rng.below((k + r) as u64) as usize);
        }
        let opts = ExecOptions::cached(&cache).faults(&spec);
        let replayed = job.run(&opts);
        let peer = job.run(&opts.engine(Engine::Peer(TransportKind::Channel)));
        let tag = format!("case {case}: K={k} R={r}");
        match (replayed, peer) {
            (Ok(a), Ok(b)) => {
                let da = a.degraded.as_ref().expect("replay degraded");
                let db = b.degraded.as_ref().expect("peer degraded");
                assert_eq!(db.coded, da.coded, "{tag}: repaired rows");
                assert_eq!(b.sim, a.sim, "{tag}: sim reports");
                assert_eq!(b.verified, a.verified, "{tag}: verified");
            }
            (Err(Error::Unrecoverable(_)), Err(Error::Unrecoverable(_))) => {}
            (a, b) => {
                let (la, lb) = (outcome(&a), outcome(&b));
                panic!("{tag}: engines disagree: replay={la} peer={lb}");
            }
        }
    }
}

// Real-proptest wrapper: CI injects the `proptest` dev-dependency and
// turns on `--features proptest-harness`; without the feature (the
// local default — the crate deliberately has no proptest dependency)
// this module compiles away and the seeded loops above stand in.
#[cfg(feature = "proptest-harness")]
mod with_proptest {
    use super::*;
    use proptest::prelude::*;

    proptest! {
        #![proptest_config(ProptestConfig::with_cases(cases() as u32))]
        #[test]
        fn any_seed_conforms(seed in any::<u64>()) {
            let mut rng = Rng::new(seed);
            check_case(seed % 8, &mut rng);
        }
    }
}

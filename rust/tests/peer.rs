//! Peer-execution conformance (the tentpole contract, C1/C2 honest):
//! for every A2A variant, over both field families, across degenerate
//! shapes, and over **all three transports**, peer-to-peer execution of
//! a sharded plan must be
//!
//! * **bit-identical** to `exec::replay` (same outputs map), and
//! * **exactly metered**: the traffic each rank measures while running
//!   — barriers crossed, per-round send maxima, messages, bandwidth —
//!   merges to the plan's static `SimReport`, and `(C1, C2)` equals
//!   [`costs::plan_statics`] with no slack in either direction.
//!
//! The second clause is what makes the round simulator an honest
//! oracle: the "no central processor" execution ships exactly the
//! traffic the paper's accounting promises, on real channels, rings
//! and sockets alike.

use dce::codes::{structured::disjoint_family, StructuredPoints};
use dce::collectives::{CauchyA2A, DftA2A, DrawLoose, PrepareShoot};
use dce::coordinator::{Engine, ExecOptions, JobConfig, PlanCache};
use dce::framework::{costs, A2aAlgo, SystematicEncode};
use dce::gf::{Field, Gf2e, GfPrime, Mat};
use dce::net::peer::run_peer;
use dce::net::transport::TransportKind;
use dce::net::{exec, plan, Collective, Packet};
use dce::util::{ipow, Rng};
use std::sync::Arc;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(20);

fn rand_inputs<F: Field>(f: &F, k: usize, w: usize, rng: &mut Rng) -> Vec<Packet> {
    (0..k)
        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
        .collect()
}

/// Compile the collective once; peer-run it over every transport and
/// pin outputs + measured traffic against replay and the plan statics.
fn assert_peer_conforms<F, B>(tag: &str, f: &F, ports: usize, k: usize, w: usize, build: B)
where
    F: Field + Sync,
    B: Fn(Vec<Packet>) -> Box<dyn Collective>,
{
    let compiled = plan::compile(ports, k, |basis| Ok(build(basis))).unwrap();
    let mut rng = Rng::new(k as u64 * 7919 + ports as u64 * 53 + w as u64);
    let inputs = rand_inputs(f, k, w, &mut rng);

    let rep = exec::replay(&compiled, f, &inputs).unwrap();
    let statics = costs::plan_statics(&compiled, w as u64);
    assert_eq!(
        (rep.report.c1, rep.report.c2),
        statics,
        "{tag}: replay report vs statics (test harness sanity)"
    );

    for kind in TransportKind::ALL {
        let peer = run_peer(&compiled, f, &inputs, kind, TIMEOUT)
            .unwrap_or_else(|e| panic!("{tag} over {kind}: {e:#}"));
        assert_eq!(peer.outputs, rep.outputs, "{tag} over {kind}: outputs");
        // The full report — per-round maxima included — not just sums.
        assert_eq!(
            peer.measured, rep.report,
            "{tag} over {kind}: measured traffic vs replay report"
        );
        assert_eq!(
            (peer.measured.c1, peer.measured.c2),
            statics,
            "{tag} over {kind}: measured (C1, C2) vs costs::plan_statics"
        );
        assert_eq!(
            (peer.measured.messages, peer.measured.bandwidth),
            (rep.report.messages, rep.report.bandwidth),
            "{tag} over {kind}: message/bandwidth counts"
        );
    }
}

#[test]
fn universal_prepare_shoot_prime_including_degenerate() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xBEE1);
    for (k, p, w) in [
        (1usize, 1usize, 1usize), // fully degenerate
        (2, 1, 1),
        (5, 1, 2),
        (16, 1, 4),
        (10, 2, 1),
        (25, 2, 3),
    ] {
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let c2 = c.clone();
        assert_peer_conforms(&format!("ps K={k} p={p} w={w}"), &f, p, k, w, move |ins| {
            Box::new(PrepareShoot::new(f, (0..k).collect(), p, c2.clone(), ins))
        });
    }
}

#[test]
fn universal_prepare_shoot_gf2e() {
    let f = Gf2e::new(8).unwrap();
    let mut rng = Rng::new(0xBEE2);
    for (k, p, w) in [(1usize, 1usize, 1usize), (13, 2, 3), (16, 1, 2)] {
        let c = Arc::new(Mat::random(&f, k, k, rng.next_u64()));
        let ff = f.clone();
        assert_peer_conforms(
            &format!("ps/gf2e K={k} p={p} w={w}"),
            &f,
            p,
            k,
            w,
            move |ins| {
                Box::new(PrepareShoot::new(
                    ff.clone(),
                    (0..k).collect(),
                    p,
                    c.clone(),
                    ins,
                ))
            },
        );
    }
}

#[test]
fn dft_a2a_both_fields() {
    let f = GfPrime::default_field();
    for (p_base, h, p, w) in [(2u64, 3u32, 1usize, 1usize), (4, 2, 3, 2), (2, 4, 1, 3)] {
        let k = ipow(p_base, h) as usize;
        assert_peer_conforms(
            &format!("dft P={p_base} H={h} p={p}"),
            &f,
            p,
            k,
            w,
            move |ins| {
                Box::new(DftA2A::new(f, (0..k).collect(), p, p_base, h, ins, false).unwrap())
            },
        );
    }
    // GF(256): q−1 = 255 = 3·5·17 — prime radixes only.
    let f = Gf2e::new(8).unwrap();
    for (p_base, p, w) in [(3u64, 2usize, 2usize), (5, 2, 1)] {
        let k = p_base as usize;
        let ff = f.clone();
        assert_peer_conforms(
            &format!("dft/gf2e P={p_base} p={p}"),
            &f,
            p,
            k,
            w,
            move |ins| {
                Box::new(
                    DftA2A::new(ff.clone(), (0..k).collect(), p, p_base, 1, ins, false).unwrap(),
                )
            },
        );
    }
}

#[test]
fn draw_loose_both_fields() {
    let f = GfPrime::default_field();
    for (n, p_base, p, w, invert) in [
        (8usize, 2u64, 1usize, 1usize, false),
        (12, 2, 3, 1, false),
        (24, 2, 1, 1, true),
        (5, 2, 1, 2, false), // H = 0 fallback (Remark 8)
    ] {
        let hmax = StructuredPoints::max_h(&f, n as u64, p_base);
        let m = n / ipow(p_base, hmax) as usize;
        let sp = StructuredPoints::new(&f, n, p_base, (0..m as u64).collect()).unwrap();
        assert_peer_conforms(
            &format!("dl n={n} P={p_base} p={p} inv={invert}"),
            &f,
            p,
            n,
            w,
            move |ins| {
                Box::new(DrawLoose::new(f, (0..n).collect(), p, &sp, ins, invert).unwrap())
            },
        );
    }
    // GF(256), radix 3: M = 2, Z = 3.
    let f = Gf2e::new(8).unwrap();
    let n = 6usize;
    let sp = StructuredPoints::new(&f, n, 3, vec![0, 1]).unwrap();
    let ff = f.clone();
    assert_peer_conforms("dl/gf2e n=6", &f, 1, n, 2, move |ins| {
        Box::new(DrawLoose::new(ff.clone(), (0..n).collect(), 1, &sp, ins, false).unwrap())
    });
}

#[test]
fn cauchy_a2a_both_fields() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xBEE4);
    for (n, p, w) in [(8usize, 1usize, 1usize), (16, 2, 2)] {
        let fam = disjoint_family(&f, n, 2, 2).unwrap();
        let pre: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
        let post: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
        assert_peer_conforms(&format!("cauchy n={n} p={p}"), &f, p, n, w, move |ins| {
            Box::new(
                CauchyA2A::new(
                    f,
                    (0..n).collect(),
                    p,
                    &fam[0],
                    &fam[1],
                    pre.clone(),
                    post.clone(),
                    ins,
                )
                .unwrap(),
            )
        });
    }
    let f = Gf2e::new(8).unwrap();
    let n = 6usize;
    let fam = disjoint_family(&f, n, 3, 2).unwrap();
    let pre: Vec<u64> = (0..n).map(|_| rng.range(1, 256)).collect();
    let post: Vec<u64> = (0..n).map(|_| rng.range(1, 256)).collect();
    let ff = f.clone();
    assert_peer_conforms("cauchy/gf2e n=6", &f, 1, n, 2, move |ins| {
        Box::new(
            CauchyA2A::new(
                ff.clone(),
                (0..n).collect(),
                1,
                &fam[0],
                &fam[1],
                pre.clone(),
                post.clone(),
                ins,
            )
            .unwrap(),
        )
    });
}

#[test]
fn systematic_framework_degenerate_shapes() {
    // The framework around the A2As at the degenerate corners the
    // contract names: K=1, R=1, p=1, W=1 (and small mixes).
    let f = GfPrime::default_field();
    let mut rng = Rng::new(0xBEE5);
    for (k, r, p, w) in [
        (1usize, 1usize, 1usize, 1usize),
        (4, 1, 1, 1),
        (1, 4, 1, 1),
        (1, 1, 1, 3),
        (2, 2, 1, 1),
        (12, 4, 2, 2),
    ] {
        let a = Arc::new(Mat::random(&f, k, r, rng.next_u64()));
        let a2 = a.clone();
        assert_peer_conforms(
            &format!("sys K={k} R={r} p={p} w={w}"),
            &f,
            p,
            k,
            w,
            move |ins| {
                Box::new(SystematicEncode::new(f, a2.clone(), ins, p, A2aAlgo::Universal).unwrap())
            },
        );
    }
}

#[test]
fn job_peer_engine_over_every_transport() {
    // The coordinator-facing path: one cached plan, three transports,
    // all bit-identical to the replay engine with identical reports.
    let cache = PlanCache::new();
    let cfg = JobConfig {
        k: 12,
        r: 4,
        w: 5,
        ..JobConfig::default()
    };
    let job = dce::coordinator::EncodeJob::synthetic(cfg).unwrap();
    let replayed = job.run(&ExecOptions::cached(&cache)).unwrap();
    for kind in TransportKind::ALL {
        let peer = job
            .run(&ExecOptions::cached(&cache).engine(Engine::Peer(kind)))
            .unwrap_or_else(|e| panic!("peer engine over {kind}: {e}"));
        assert_eq!(peer.verified, Some(true), "{kind}");
        assert_eq!(peer.sim, replayed.sim, "{kind}: measured vs replay report");
        assert_eq!(peer.cost, replayed.cost, "{kind}");
    }
    // Three engine runs, one shape: exactly one compile.
    assert_eq!(cache.len(), 1);
    assert_eq!(cache.stats().1, 1);
}
